"""Seeded, clock-agnostic fault-injection plans (DESIGN.md §14).

Clipper's robustness claim (paper §1, §4.4) is that the serving layer keeps
rendering accurate, low-latency predictions *despite* failing and straggling
model containers. A ``FaultPlan`` makes that claim testable: it is a frozen
description of what goes wrong — replica crashes, crash-then-recover
schedules, transient per-batch errors, latency-degradation windows — that
can be attached to any workload/cluster/pipeline scenario. Everything is a
pure function of (plan seed, virtual time, replica identity): the plan
never reads a wall clock and every random stream is seeded per replica, so
a faulted run is byte-identical from its seed, exactly like a healthy one.

Ground truth vs observation: the plan drives what *actually* happens inside
``JaxModelContainer.pred_batch_timed`` (raise on crash, raise transient
errors, multiply service time). The serving layer never reads the plan —
it must *detect* failures through missed completions and recover through
requeue/retry/hedge (``Clipper`` with a :class:`RecoveryPolicy`), the same
information boundary a real cluster has.

Spec grammar (CLI ``--fault`` and :meth:`FaultPlan.from_specs`):

* ``crash:<model>:<replica>@<at>`` — permanent crash at virtual second
  ``at``; every batch in flight or dispatched after is silently lost.
* ``crash:<model>:<replica>@<at>:<recover_at>`` — crash-then-recover: the
  replica is dead on ``[at, recover_at)`` and serves normally after.
* ``flaky:<model>:<replica>:<p>`` — each dispatched batch fails fast with
  probability ``p`` (an error response, not a silent loss).
* ``slow:<model>:<replica>:<factor>`` — multiply every service time by
  ``factor`` (latency degradation / brownout).
* ``slow:<model>:<replica>:<factor>@<from>:<until>`` — degradation window.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.containers import (ContainerCrashed, ReplicaSet,
                                   TransientError)

KINDS = ("crash", "flaky", "slow")


@dataclass(frozen=True)
class FaultSpec:
    """One injected fault on one replica of one model."""

    kind: str                       # crash | flaky | slow
    model: str
    replica: int
    at: float = 0.0                 # crash time (crash)
    recover_at: Optional[float] = None   # None = permanent (crash)
    p_error: float = 0.0            # per-batch error probability (flaky)
    factor: float = 1.0             # service-time multiplier (slow)
    slow_from: float = 0.0          # degradation window (slow)
    slow_until: float = float("inf")

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}: {self.kind!r}")
        if self.replica < 0:
            raise ValueError(f"replica must be >= 0: {self.replica}")
        if self.kind == "crash" and self.recover_at is not None \
                and self.recover_at <= self.at:
            raise ValueError(
                f"recover_at {self.recover_at} must be > at {self.at}")
        if self.kind == "flaky" and not 0.0 <= self.p_error <= 1.0:
            raise ValueError(f"p_error must be in [0, 1]: {self.p_error}")
        if self.kind == "slow" and self.factor <= 0.0:
            raise ValueError(f"factor must be > 0: {self.factor}")

    def describe(self) -> str:
        """Canonical spec string (round-trips through ``parse_fault``)."""
        if self.kind == "crash":
            s = f"crash:{self.model}:{self.replica}@{self.at:g}"
            return s + (f":{self.recover_at:g}"
                        if self.recover_at is not None else "")
        if self.kind == "flaky":
            return f"flaky:{self.model}:{self.replica}:{self.p_error:g}"
        s = f"slow:{self.model}:{self.replica}:{self.factor:g}"
        if self.slow_from > 0.0 or self.slow_until != float("inf"):
            return s + f"@{self.slow_from:g}:{self.slow_until:g}"
        return s


def parse_fault(spec: str) -> FaultSpec:
    """Parse one ``--fault`` spec string (grammar in the module docstring)."""
    try:
        kind, rest = spec.split(":", 1)
        if kind == "crash":
            head, at_part = rest.split("@", 1)
            model, replica = head.rsplit(":", 1)
            times = at_part.split(":")
            if len(times) not in (1, 2):
                raise ValueError("expected @<at> or @<at>:<recover_at>")
            return FaultSpec("crash", model, int(replica),
                             at=float(times[0]),
                             recover_at=(float(times[1])
                                         if len(times) == 2 else None))
        if kind == "flaky":
            model, replica, p = rest.rsplit(":", 2)
            return FaultSpec("flaky", model, int(replica), p_error=float(p))
        if kind == "slow":
            if "@" in rest:
                head, win = rest.split("@", 1)
                lo, hi = win.split(":")
                model, replica, factor = head.rsplit(":", 2)
                return FaultSpec("slow", model, int(replica),
                                 factor=float(factor), slow_from=float(lo),
                                 slow_until=float(hi))
            model, replica, factor = rest.rsplit(":", 2)
            return FaultSpec("slow", model, int(replica),
                             factor=float(factor))
        raise ValueError(f"unknown fault kind {kind!r}; have {KINDS}")
    except (ValueError, IndexError) as e:
        raise ValueError(f"bad fault spec {spec!r}: {e}") from None


class ReplicaFaults:
    """Runtime fault state for one replica — the merged view of every spec
    targeting it, with its own seeded rng stream for transient errors.

    Consumed by ``JaxModelContainer.pred_batch_timed(inputs, now=...)``:
    ``check_dispatch`` raises before any compute when the replica is dead or
    rolls a transient error; ``multiplier`` scales the modeled service time;
    ``check_service`` loses the batch when the crash strikes mid-service.
    All decisions are functions of (seed stream, virtual now) only."""

    def __init__(self, *, crash_at: Optional[float] = None,
                 recover_at: Optional[float] = None, p_error: float = 0.0,
                 factor: float = 1.0, slow_from: float = 0.0,
                 slow_until: float = float("inf"),
                 rng: Optional[np.random.Generator] = None):
        self.crash_at = crash_at
        self.recover_at = recover_at
        self.p_error = p_error
        self.factor = factor
        self.slow_from = slow_from
        self.slow_until = slow_until
        self.rng = rng if rng is not None else np.random.default_rng(0)

    def crashed(self, now: float) -> bool:
        """Ground truth: is the replica dead at ``now``? (The serving layer
        must not call this for routing — detection is its job; only the
        recovery *probe* consults it, modeling a health check that the
        replica answers once it is back.)"""
        return (self.crash_at is not None and now >= self.crash_at
                and (self.recover_at is None or now < self.recover_at))

    def multiplier(self, now: float) -> float:
        """Service-time multiplier in effect at ``now`` (1.0 = healthy)."""
        if self.factor != 1.0 and self.slow_from <= now < self.slow_until:
            return self.factor
        return 1.0

    def check_dispatch(self, now: float) -> None:
        """Raise if a batch dispatched at ``now`` does not execute."""
        if self.crashed(now):
            raise ContainerCrashed(f"replica crashed at {self.crash_at}")
        if self.p_error and self.rng.random() < self.p_error:
            raise TransientError("injected transient batch error")

    def check_service(self, now: float, service: float) -> None:
        """Raise if the crash strikes while the batch is executing — the
        work is lost even though dispatch succeeded."""
        if (self.crash_at is not None
                and now < self.crash_at <= now + service):
            raise ContainerCrashed(
                f"replica crashed mid-service at {self.crash_at}")


@dataclass(frozen=True)
class FaultPlan:
    """A reproducible set of injected faults: specs + the seed every
    transient-error stream derives from. Attach with :func:`attach_faults`;
    replicas the autoscaler adds later are fresh hardware and get none."""

    specs: Tuple[FaultSpec, ...] = ()
    seed: int = 0

    @classmethod
    def from_specs(cls, specs: Sequence[Union[str, FaultSpec]],
                   seed: int = 0) -> "FaultPlan":
        parsed = tuple(parse_fault(s) if isinstance(s, str) else s
                       for s in specs)
        return cls(parsed, seed)

    def describe(self) -> Tuple[str, ...]:
        return tuple(s.describe() for s in self.specs)

    def for_replica(self, model: str, replica: int
                    ) -> Optional[ReplicaFaults]:
        """Merged runtime fault state for one replica (None = healthy).
        At most one crash window per replica; later crash specs override
        earlier ones. The rng stream is seeded from (plan seed, model,
        replica) so independently-constructed plans with the same seed roll
        identical error streams."""
        mine = [s for s in self.specs
                if s.model == model and s.replica == replica]
        if not mine:
            return None
        kw: Dict = {}
        for s in mine:
            if s.kind == "crash":
                kw["crash_at"], kw["recover_at"] = s.at, s.recover_at
            elif s.kind == "flaky":
                kw["p_error"] = s.p_error
            else:
                kw["factor"] = s.factor
                kw["slow_from"], kw["slow_until"] = s.slow_from, s.slow_until
        rng = np.random.default_rng(
            [self.seed & 0x7FFFFFFF, zlib.crc32(model.encode()),
             replica, 23])
        return ReplicaFaults(rng=rng, **kw)


@dataclass(frozen=True)
class RecoveryPolicy:
    """How the frontend survives the plan (DESIGN.md §14).

    * **Detection** — every dispatched batch arms a timeout at
      ``max(detect_factor × E[service], min_timeout)`` (``min_timeout``
      ``None`` = the SLO). A missed completion marks the replica suspected
      (out of routing), drains its queued backlog to a live replica via the
      ordinary ``requeue_to`` path, and retries the lost batch.
    * **Retries** — per-query per-model budget of ``max_retries``
      re-dispatches with exponential backoff ``backoff_base × 2^attempt``;
      exhausted queries fall back to straggler mitigation (render without
      the failed model at the deadline).
    * **Hedging** — when a batch outlives ``max(hedge_factor × E[service],
      hedge_min)`` (``hedge_min`` ``None`` = half the SLO), its unanswered
      queries are re-enqueued once on the best alternate replica;
      whichever copy completes first wins.
    * **Recovery** — suspected replicas are health-probed each dispatch
      round; once the fault window has passed they rejoin routing.
    """

    detect_factor: float = 6.0
    min_timeout: Optional[float] = None       # None = the frontend SLO
    max_retries: int = 2
    backoff_base: float = 0.002
    hedge: bool = True
    hedge_factor: float = 3.0
    hedge_min: Optional[float] = None         # None = half the SLO


@dataclass(frozen=True)
class RequestFaults:
    """Per-request transient failures for the continuous-batching LMServer:
    request ``rid`` fails with probability ``p_error``, decided by a pure
    hash of (seed, rid) — order-independent, byte-identical per seed. A
    failed request still finishes decoding (the tokens exist) but carries
    ``Request.failed = True`` for downstream policy — ``LMCascade``
    escalates failed drafts and degrades failed verifies to the draft
    answer."""

    p_error: float = 0.0
    seed: int = 0

    def failed(self, request_id: int) -> bool:
        from repro.obs.tracer import sample_decision
        # decorrelate from the tracer's sampling decisions on the same ids
        return sample_decision(self.seed ^ 0x5DEECE66D, request_id + 1,
                               self.p_error)


def attach_faults(replica_sets: Dict[str, ReplicaSet],
                  plan: FaultPlan) -> int:
    """Install the plan's per-replica fault state on existing containers;
    returns the number of replicas faulted. Specs naming unknown models or
    out-of-range replica slots raise (a silently inert fault plan would
    make a passing robustness test meaningless)."""
    known = set(replica_sets)
    for s in plan.specs:
        if s.model not in known:
            raise KeyError(f"fault spec {s.describe()!r}: unknown model "
                           f"{s.model!r}; have {sorted(known)}")
        if s.replica >= len(replica_sets[s.model].replicas):
            raise KeyError(f"fault spec {s.describe()!r}: model {s.model!r} "
                           f"has {len(replica_sets[s.model].replicas)} "
                           "replica slots")
    n = 0
    for mid, rs in replica_sets.items():
        for ri in range(len(rs.replicas)):
            rf = plan.for_replica(mid, ri)
            if rf is not None:
                rs.set_faults(ri, rf)
                n += 1
    return n
