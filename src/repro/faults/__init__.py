"""Fault injection and recovery policy (DESIGN.md §14)."""

from repro.faults.plan import (FaultPlan, FaultSpec, RecoveryPolicy,
                               ReplicaFaults, RequestFaults, attach_faults,
                               parse_fault)

__all__ = ["FaultPlan", "FaultSpec", "RecoveryPolicy", "ReplicaFaults",
           "RequestFaults", "attach_faults", "parse_fault"]
