"""LM serving engine: continuous batching with Clipper admission control.

Requests (token prompts) enter an AIMD-governed admission queue (paper §4.3
applied to prefill); admitted prompts are prefilled in bucket-padded batches
and parked in decode *slots*; every engine step advances all active slots by
one token through a single jitted decode step (continuous batching). Slot
caches live in one donated buffer, so decode never reallocates.

Device-resident hot path (DESIGN.md §11): the *fused* decode step folds
sampling, per-slot length advance, EOS/max-token done-masking, and the
next-token feedback into the one jitted function — the host sees exactly one
compact ``[tokens ‖ done]`` transfer per step (O(1) in slots, down from the
O(slots) per-step syncs of the reference loop, kept here as ``fused=False``
for parity tests and before/after benchmarks). Admission likewise scatters
the whole prefilled batch into the donated slot cache with one jitted
masked-select, and prompts are padded up a geometric *length ladder*
(``core.batching.prompt_length_ladder``) so distinct prefill compilations
are bounded by the ladder, not by the workload's distinct prompt lengths —
and mixed-length traces no longer head-of-line block behind same-length
grouping.

This is deliberately the same architecture a TPU pod would run — the jitted
prefill/decode functions come from launch/steps.py-style builders with the
production shardings; here they execute on the local mesh.

Telemetry note: in wall-clock mode the first dispatch of each shape bucket
includes XLA compilation in its measured service time — a real cold-start
the tail percentiles deliberately keep. Calibrated-simulation mode
(``service_model`` + ``VirtualClock``) has no such transient."""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batching import AIMDController, bucket, prompt_length_ladder
from repro.core import metrics as M
from repro.core.metrics import MetricsRegistry
from repro.distributed.sharding import sharding_context
from repro.models.api import Model
from repro.models.common import get_attention_backend
from repro.serving.sampler import sample

# Calibrated-simulation hook (DESIGN.md §8): maps ("prefill", batch, tokens)
# or ("decode", batch, 1) to modeled service seconds, where batch is the
# *executed* shape (padded prefill bucket; all decode slots) — the shapes the
# wall-clock engine actually pays for. With one installed, the engine
# advances its (advanceable) clock by modeled time instead of measuring
# wall-clock — deterministic, byte-identical telemetry from a seed.
ServiceModel = Callable[[str, int, int], float]


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_time: float
    tokens: List[int] = field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None
    prefill_time: Optional[float] = None
    finish_time: Optional[float] = None
    # span tracing (repro.obs): the request's root span, plus the exact
    # phase boundaries latency attribution partitions the SLO budget along
    trace: Optional[Any] = None
    dispatch_time: Optional[float] = None     # left the queue for prefill
    prefill_end: Optional[float] = None       # prefill done, decode begins
    # injected per-request failure (repro.faults.RequestFaults): the tokens
    # exist but the answer is unusable — downstream policy (LMCascade)
    # escalates failed drafts and degrades failed verifies
    failed: bool = False


def make_fused_decode_fn(model: Model, mesh, rules, *, temperature: float,
                         eos: int, max_len: int):
    """Build the fused device-resident decode step (the engine's hot loop).

    Signature: ``(params, cache, lengths, cur, active, gen, max_new, key)
    -> (packed, cache, lengths, cur, active, gen)`` where ``packed`` is the
    single per-step host transfer ``concat([tokens, done])`` ([2*slots]
    int32) and everything else stays on device. Done semantics mirror the
    reference loop token-for-token: a slot finishes when its sampled token
    is EOS, its generated count reaches ``max_new``, or its advanced context
    length reaches ``max_len - 1``."""

    def fused(params, cache, lengths, cur, active, gen, max_new, key):
        with sharding_context(mesh, rules):
            logits, cache = model.decode_step(params, cache, cur, lengths)
        toks = sample(logits, key, temperature=temperature)
        act = active.astype(jnp.int32)
        new_len = lengths + act
        new_cur = jnp.where(active[:, None], toks[:, None], cur)
        new_gen = gen + act
        done = active & ((toks == eos) | (new_gen >= max_new)
                         | (new_len >= max_len - 1))
        packed = jnp.concatenate([toks.astype(jnp.int32),
                                  done.astype(jnp.int32)])
        return packed, cache, new_len, new_cur, active & ~done, new_gen

    return fused


def batched_scatter(cache, pcache, slot_mask, src_idx):
    """Scatter a whole prefilled batch into the slot cache in one shot.

    ``slot_mask``: [slots] bool — slots receiving a new request;
    ``src_idx``: [slots] int32 — row of ``pcache`` for each receiving slot
    (arbitrary where the mask is False). Implemented as gather + masked
    select per leaf, so the donated cache is rematerialized once for the
    whole admitted batch instead of once per request (`_scatter_cache`).
    Leaves are [B] (lengths), or layer-stacked [L, B, ...] with an optional
    shorter dim-2 (e.g. encoder memory) padded up to the destination."""

    def leaf(dv, sv):
        if dv.ndim == 1:
            return jnp.where(slot_mask, sv[src_idx].astype(dv.dtype), dv)
        sl = jnp.take(sv, src_idx, axis=1)
        if sl.ndim > 2 and sl.shape[2] < dv.shape[2]:
            pad = dv.shape[2] - sl.shape[2]
            sl = jnp.pad(sl, [(0, 0), (0, 0), (0, pad)]
                         + [(0, 0)] * (sl.ndim - 3))
        m = slot_mask.reshape((1, slot_mask.shape[0]) + (1,) * (sl.ndim - 2))
        return jnp.where(m, sl.astype(dv.dtype), dv)

    return jax.tree.map(leaf, cache, pcache)


def _admit_state(lengths, cur, active, gen, max_new, slot_mask, src_idx,
                 vlens, firsts, maxnews):
    """Batched slot-state update at admission (device-resident mirror of the
    per-request bookkeeping): one dispatch for the whole admitted batch."""
    new_len = jnp.where(slot_mask, vlens[src_idx], lengths)
    new_cur = jnp.where(slot_mask[:, None], firsts[src_idx][:, None], cur)
    new_gen = jnp.where(slot_mask, 1, gen)
    new_maxn = jnp.where(slot_mask, maxnews[src_idx], max_new)
    return new_len, new_cur, active | slot_mask, new_gen, new_maxn


class LMServer:
    """Continuous-batching server for one Model."""

    def __init__(self, model: Model, mesh, rules, *, slots: int = 8,
                 max_len: int = 256, slo: float = 0.5,
                 temperature: float = 0.0, eos_token: int = -1,
                 seed: int = 0, clock: Callable[[], float] = time.perf_counter,
                 metrics: Optional[MetricsRegistry] = None,
                 service_model: Optional[ServiceModel] = None,
                 model_id: str = "lm", admission_control=None,
                 fused: bool = True, prefill_slo_frac: float = 0.5,
                 pad_prompts: Optional[bool] = None,
                 on_finish: Optional[Callable[["Request"], None]] = None,
                 tracer=None, faults=None, audit=None):
        self.model = model
        self.mesh = mesh
        self.rules = rules
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.eos = eos_token
        self.slo = slo
        self.clock = clock
        self.service_model = service_model
        if service_model is not None and not hasattr(clock, "advance"):
            # modeled service times with a wall clock would mix timelines:
            # service_s modeled, latencies/throughput wall-clock
            raise ValueError(
                "service_model requires an advanceable clock "
                "(e.g. metrics.VirtualClock) so the whole report shares "
                "one timeline")
        self.model_id = model_id
        self.metrics = metrics if metrics is not None else MetricsRegistry(slo)
        # span tracing (repro.obs, DESIGN.md §13): None = tracing off
        self.tracer = tracer
        # control-plane decision audit (repro.obs.audit, DESIGN.md §15):
        # admission sheds record their backlog/wait evidence here. None = off
        self.audit = audit
        # probe state for repro.obs.timeseries windowed rates
        self._ts_prev: Dict[str, float] = {}
        # SLO-aware admission control (repro.cluster.admission): consulted
        # per submit; rejected requests are shed before they touch the
        # queue. Distinct from ``self.admission``, the AIMD *batch-size*
        # controller that governs prefill admission below.
        self.admission_control = admission_control
        self.shed = 0
        # cascade hook (repro.pipeline.cascade): invoked once per request at
        # completion, after the engine's own bookkeeping — a draft engine's
        # callback decides whether to escalate to a verify engine
        self.on_finish = on_finish
        # per-request fault injection (repro.faults.RequestFaults): a pure
        # seeded hash of the request id decides transient failures, so a
        # faulted LM run stays byte-identical per seed. None = off.
        self.faults = faults
        # prefill-only service time gets its own latency budget — a fraction
        # of the request SLO — rather than the full SLO, which would bias
        # max_batch high (prefill is only the first leg of a request)
        self.prefill_slo_frac = prefill_slo_frac
        self.admission = AIMDController(slo * prefill_slo_frac, additive=1,
                                        init=1, max_batch=slots)
        self.fused = fused
        # prompt-length ladder (only meaningful on the fused path; the
        # reference path reproduces the PR-3 same-length grouping exactly)
        if pad_prompts is None:
            pad_prompts = fused and bool(model.extras.get("prompt_pad"))
        self.pad_prompts = pad_prompts
        self._pad_cap = min(max_len,
                            int(model.extras.get("prompt_pad_cap", max_len)))
        self.length_ladder = prompt_length_ladder(self._pad_cap)
        self.rng = jax.random.PRNGKey(seed)
        self._queue: List[Request] = []
        self._active: Dict[int, Request] = {}      # slot -> request
        self._next_id = 0
        self.completed: Dict[int, Request] = {}
        # hot-path instrumentation (bench_serving reads these)
        self.decode_steps = 0
        self.decode_host_syncs = 0
        self.prefill_dispatches = 0
        # prefill dispatches per ladder rung (padded prompt length) — which
        # rungs the workload actually exercises (repro.obs.timeseries)
        self.rung_dispatches: Dict[int, int] = {}

        self.cache = model.init_cache(slots, max_len)
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.cur_tokens = jnp.zeros((slots, 1), jnp.int32)
        self.active_mask = jnp.zeros((slots,), jnp.bool_)
        self.gen_counts = jnp.zeros((slots,), jnp.int32)
        self.max_new = jnp.zeros((slots,), jnp.int32)

        if fused:
            self._decode_fused = jax.jit(
                make_fused_decode_fn(model, mesh, rules,
                                     temperature=temperature, eos=eos_token,
                                     max_len=max_len),
                donate_argnums=(1, 2, 3, 4, 5))
            self._scatter_jit = jax.jit(batched_scatter, donate_argnums=(0,))
            self._admit_state_jit = jax.jit(_admit_state,
                                            donate_argnums=(0, 1, 2, 3, 4))
        else:
            def decode_fn(params, cache, tokens, lengths, key):
                with sharding_context(mesh, rules):
                    logits, cache = model.decode_step(params, cache, tokens,
                                                      lengths)
                toks = sample(logits, key, temperature=temperature)
                return toks, cache

            self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self._prefill_cache: Dict[Any, Any] = {}   # shape key -> jitted prefill

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               now: Optional[float] = None) -> int:
        """Enqueue a prompt. ``now`` (when given) must be on the same
        timeline as this server's ``clock`` — completion telemetry computes
        ``finish - arrival`` with ``clock()``, so a foreign timestamp (e.g.
        0.0 against the default wall clock) yields garbage latencies."""
        rid = self._next_id
        self._next_id += 1
        at = self.clock() if now is None else now
        self.metrics.inc(M.QUERIES_SUBMITTED)
        self.metrics.mark(at)
        trace = None
        if self.tracer is not None:
            # root span: the request's whole lifecycle; budget = full SLO
            trace = self.tracer.start_trace(
                "request", "lm", at, budget_s=self.slo,
                attrs={"rid": rid, "prompt_len": int(len(prompt)),
                       "max_new": max_new_tokens})
        if (self.admission_control is not None
                and not self.admission_control.admit_lm(self, at)):
            self.metrics.inc(M.QUERIES_SHED)
            self.shed += 1
            if self.tracer is not None:
                self.tracer.event(trace, "shed", "lm.admission", at)
                self.tracer.end_trace(trace, at, status="shed")
            return rid              # shed — never queued, never completes
        req = Request(rid, np.asarray(prompt, np.int32), max_new_tokens, at)
        req.trace = trace
        self._queue.append(req)
        return rid

    def est_request_service(self) -> float:
        """Observed engine-seconds per completed request — the backlog-drain
        estimate admission control consumes. Zero until the first completion
        (admit everything while there is no signal)."""
        done = self.metrics.counter(M.QUERIES_COMPLETED)
        h = self.metrics.hist(M.SERVICE, model=self.model_id)
        if not done or h is None:
            return 0.0
        return h.total / done

    def _service_time(self, kind: str, batch: int, tokens: int,
                      t0: float) -> float:
        """Measured wall-clock, or modeled time (advancing the injected
        clock) in calibrated-simulation mode."""
        if self.service_model is None:
            return self.clock() - t0
        dt = self.service_model(kind, batch, tokens)
        self.clock.advance(dt)      # ctor guarantees the clock is advanceable
        return dt

    @property
    def prefill_compiles(self) -> int:
        """Distinct compiled prefill shapes so far — with the length ladder
        this is bounded by (batch rungs × ladder rungs), not by the number
        of distinct prompt lengths in the trace."""
        return len(self._prefill_cache)

    def _prefill_jit(self, b: int, plen: int, padded: bool):
        key = (b, plen, padded)
        if key not in self._prefill_cache:
            if self.tracer is not None:
                # compile events mark the cold-start tail wall-clock mode
                # pays per new (batch, length) shape (module docstring)
                self.tracer.global_event(
                    "compile", "engine.prefill", self.clock(),
                    attrs={"batch": b, "prompt_len": plen, "padded": padded})
            if padded:
                def fn(params, tokens, lengths):
                    with sharding_context(self.mesh, self.rules):
                        return self.model.prefill(
                            params, {"tokens": tokens, "lengths": lengths},
                            max_len=self.max_len)
            else:
                def fn(params, tokens):
                    with sharding_context(self.mesh, self.rules):
                        return self.model.prefill(params, {"tokens": tokens},
                                                  max_len=self.max_len)
            self._prefill_cache[key] = jax.jit(fn)
        return self._prefill_cache[key]

    # ------------------------------------------------------------------
    def _take_batch(self, n: int):
        """Dequeue up to ``n`` requests for one prefill dispatch; returns
        ``(batch, padded)``.

        Ladder mode (``padded=True``): the FIFO prefix whose prompts fit
        the pad cap — mixed lengths ride together (no same-length
        head-of-line blocking). Fallback (reference mode, moe, or an
        over-cap head prompt): the PR-3 same-length group around the
        queue head."""
        if self.pad_prompts and len(self._queue[0].prompt) <= self._pad_cap:
            batch: List[Request] = []
            while (self._queue and len(batch) < n
                   and len(self._queue[0].prompt) <= self._pad_cap):
                batch.append(self._queue.pop(0))
            return batch, True
        # same-length group (prefill has no per-sample prompt masking here;
        # grouping by length avoids junk-token attention)
        plen = len(self._queue[0].prompt)
        batch = []
        for r in list(self._queue):
            if len(r.prompt) == plen and len(batch) < n:
                batch.append(r)
                self._queue.remove(r)
        return batch, False

    def _admit(self, params) -> None:
        free = [s for s in range(self.slots) if s not in self._active]
        if not free or not self._queue:
            return
        n = min(len(free), len(self._queue), self.admission.max_batch_size)
        batch, padded = self._take_batch(n)
        n = len(batch)
        if n == 0:
            return
        self.metrics.observe(M.QUEUE_DEPTH, n + len(self._queue))
        if padded:
            plen = bucket(max(len(r.prompt) for r in batch),
                          ladder=self.length_ladder)
        else:
            plen = len(batch[0].prompt)
        nb = bucket(n, cap=self.slots)
        toks = np.zeros((nb, plen), np.int32)
        vlens = np.full((nb,), plen, np.int32)
        for i, r in enumerate(batch):
            L = len(r.prompt)
            toks[i, :L] = r.prompt
            vlens[i] = L
        t0 = self.clock()
        if padded:
            logits, pcache = self._prefill_jit(nb, plen, True)(
                params, jnp.asarray(toks), jnp.asarray(vlens))
        else:
            logits, pcache = self._prefill_jit(nb, plen, False)(
                params, jnp.asarray(toks))
        jax.block_until_ready(logits)
        self.prefill_dispatches += 1
        self.rung_dispatches[int(plen)] = (
            self.rung_dispatches.get(int(plen), 0) + 1)
        # the service model is charged the *executed* shape (padded bucket),
        # matching what wall-clock mode measures for the same workload
        dt = self._service_time("prefill", nb, plen, t0)
        self.admission.record(n, dt)
        self.metrics.inc(M.QUERIES_SUBMITTED, n, model=self.model_id)
        self._observe_batch(n, dt)
        self.metrics.mark(self.clock())
        if self.tracer is not None:
            # queue span: arrival -> dispatch; prefill span: the batch's
            # service interval, budgeted at the prefill share of the SLO
            for r in batch:
                r.dispatch_time = t0
                r.prefill_end = t0 + dt
                if r.trace is not None:
                    self.tracer.add_span(r.trace, "queue", "lm.queue",
                                         r.arrival_time, t0)
                    self.tracer.add_span(
                        r.trace, "prefill", "lm.prefill", t0, t0 + dt,
                        budget_s=self.slo * self.prefill_slo_frac,
                        attrs={"batch": n, "padded_len": int(plen)})
        self.rng, k = jax.random.split(self.rng)
        first = sample(logits, k, temperature=self.temperature)
        first_np = np.asarray(first)
        if self.fused:
            # one jitted scatter + one jitted state update for the whole
            # admitted batch (the donated cache is rematerialized once, not
            # once per request)
            slot_mask = np.zeros((self.slots,), bool)
            src_idx = np.zeros((self.slots,), np.int32)
            maxnews = np.zeros((nb,), np.int32)
            for i, r in enumerate(batch):
                s = free[i]
                r.slot = s
                r.prefill_time = dt
                r.tokens.append(int(first_np[i]))
                self._active[s] = r
                slot_mask[s] = True
                src_idx[s] = i
                maxnews[i] = r.max_new_tokens
            slot_mask = jnp.asarray(slot_mask)
            src_idx = jnp.asarray(src_idx)
            self.cache = self._scatter_jit(self.cache, pcache, slot_mask,
                                           src_idx)
            (self.lengths, self.cur_tokens, self.active_mask,
             self.gen_counts, self.max_new) = self._admit_state_jit(
                self.lengths, self.cur_tokens, self.active_mask,
                self.gen_counts, self.max_new, slot_mask, src_idx,
                jnp.asarray(vlens), first.astype(jnp.int32),
                jnp.asarray(maxnews))
        else:
            # reference path: per-request scatter, per-slot host bookkeeping
            for i, r in enumerate(batch):
                s = free[i]
                r.slot = s
                r.prefill_time = dt
                r.tokens.append(int(first_np[i]))
                self._active[s] = r
                self.cache = _scatter_cache(self.cache, pcache, i, s)
                self.lengths = self.lengths.at[s].set(int(vlens[i]))
                self.cur_tokens = self.cur_tokens.at[s, 0].set(
                    int(first_np[i]))

    def _decode_once(self, params) -> None:
        if not self._active:
            return
        if self.fused:
            self._decode_once_fused(params)
        else:
            self._decode_once_reference(params)

    def _decode_once_fused(self, params) -> None:
        t0 = self.clock()
        self.rng, k = jax.random.split(self.rng)
        (packed, self.cache, self.lengths, self.cur_tokens,
         self.active_mask, self.gen_counts) = self._decode_fused(
            params, self.cache, self.lengths, self.cur_tokens,
            self.active_mask, self.gen_counts, self.max_new, k)
        out = np.asarray(packed)            # the ONE host transfer per step
        self.decode_host_syncs += 1
        toks, done = out[:self.slots], out[self.slots:].astype(bool)
        n_active = len(self._active)
        # executed shape: the jitted decode computes every slot each step
        # regardless of how many are active, like the wall-clock engine
        dt = self._service_time("decode", self.slots, 1, t0)
        # decode steps dominate LM serving work — they count as dispatched
        # batches alongside prefill, so the report reflects the whole run
        self._observe_batch(n_active, dt)
        self.decode_steps += 1
        for s, r in list(self._active.items()):
            r.tokens.append(int(toks[s]))
            if done[s]:
                self._finish(s, r)

    def _decode_once_reference(self, params) -> None:
        """PR-3 hot path, kept verbatim as the parity/benchmark baseline:
        per-slot ``int()`` pulls and per-slot ``.at[].set`` feedback — the
        O(slots) host round-trips the fused step eliminates."""
        t0 = self.clock()
        self.rng, k = jax.random.split(self.rng)
        toks, self.cache = self._decode(params, self.cache, self.cur_tokens,
                                        self.lengths, k)
        toks = np.asarray(toks)
        self.decode_host_syncs += 1
        n_active = len(self._active)
        dt = self._service_time("decode", self.slots, 1, t0)
        self._observe_batch(n_active, dt)
        self.decode_steps += 1
        self.lengths = self.lengths + jnp.asarray(
            [1 if s in self._active else 0 for s in range(self.slots)],
            jnp.int32)
        for s, r in list(self._active.items()):
            t = int(toks[s])
            r.tokens.append(t)
            self.cur_tokens = self.cur_tokens.at[s, 0].set(t)
            cur_len = int(self.lengths[s])
            self.decode_host_syncs += 1     # per-slot device read
            if (t == self.eos or len(r.tokens) >= r.max_new_tokens
                    or cur_len >= self.max_len - 1):
                self._finish(s, r)

    def _finish(self, s: int, r: Request) -> None:
        r.done = True
        if self.faults is not None and self.faults.failed(r.request_id):
            r.failed = True
            self.metrics.inc_both(M.FAULTS_TRANSIENT, model=self.model_id)
            self.metrics.inc_both(M.MODEL_FAILURES, model=self.model_id)
            if self.tracer is not None and r.trace is not None:
                self.tracer.event(r.trace, "fault.request_failed",
                                  "lm.fault", self.clock())
        r.finish_time = self.clock()
        self.completed[r.request_id] = r
        del self._active[s]
        if self.tracer is not None and r.trace is not None:
            # decode span: per-step work aggregated into one interval from
            # prefill end to completion; the attribution is an exact
            # partition queue + prefill + decode == end-to-end latency
            self.tracer.add_span(
                r.trace, "decode", "lm.decode", r.prefill_end, r.finish_time,
                budget_s=self.slo * (1.0 - self.prefill_slo_frac),
                attrs={"tokens": len(r.tokens)})
            latency = r.finish_time - r.arrival_time
            attribution = None
            if latency > 0:
                attribution = {
                    "lm.queue": r.dispatch_time - r.arrival_time,
                    "lm.prefill": r.prefill_end - r.dispatch_time,
                    "lm.decode": r.finish_time - r.prefill_end,
                }
            self.tracer.end_trace(r.trace, r.finish_time,
                                  attribution=attribution,
                                  attrs={"tokens": len(r.tokens)})
        # tagged per-model so multi-model cluster reports can separate LM
        # completions from frontend ones
        self.metrics.inc_both(M.QUERIES_COMPLETED, model=self.model_id)
        self.metrics.observe_latency(r.finish_time - r.arrival_time,
                                     model=self.model_id)
        self.metrics.mark(r.finish_time)
        if self.on_finish is not None:
            self.on_finish(r)

    def _observe_batch(self, size: int, service: float) -> None:
        """One dispatched batch (prefill or decode) into the shared schema —
        both dispatch paths must stay in lockstep."""
        self.metrics.inc_both(M.BATCHES, model=self.model_id)
        self.metrics.observe_both(M.BATCH_SIZE, size, model=self.model_id)
        self.metrics.observe_both(M.SERVICE, service, model=self.model_id)

    @property
    def pending(self) -> bool:
        """True while any request is queued or decoding — the public drive
        predicate (ScenarioRunner and external loops use this, not the
        private queue/slot state)."""
        return bool(self._queue or self._active)

    def timeseries_probe(self, now: float, dt: float) -> Dict[str, float]:
        """FleetSampler probe: slot occupancy, queue depth, AIMD prefill
        budget, shed/throughput rates, and per-rung dispatch rates
        (repro.obs.timeseries, DESIGN.md §15). Read-only on the engine."""
        mid = self.model_id

        def rate(key: str, cur: float) -> float:
            prev = self._ts_prev.get(key, 0.0)
            self._ts_prev[key] = cur
            return (cur - prev) / dt

        out = {
            f"lm.slots_active.{mid}": float(len(self._active)),
            f"lm.slots_free.{mid}": float(self.slots - len(self._active)),
            f"lm.queue_depth.{mid}": float(len(self._queue)),
            f"lm.aimd_budget.{mid}": float(self.admission.max_batch_size),
            f"lm.est_service.{mid}": self.est_request_service(),
            # model-scoped (not the frontend's global names): a cascade
            # samples two engines into one document without collisions
            f"lm.lambda.{mid}": rate(
                "submitted", self.metrics.counter(M.QUERIES_SUBMITTED)),
            f"lm.throughput.{mid}": rate(
                "completed", self.metrics.counter(M.QUERIES_COMPLETED)),
            f"lm.shed_rate.{mid}": rate(
                "shed", self.metrics.counter(M.QUERIES_SHED)),
            f"lm.decode_steps.{mid}": rate("decode", self.decode_steps),
            f"lm.prefill_dispatches.{mid}": rate(
                "prefill", self.prefill_dispatches),
        }
        for plen, n in sorted(self.rung_dispatches.items()):
            out[f"lm.rung_dispatches.{plen}.{mid}"] = rate(
                f"rung.{plen}", n)
        return out

    def step(self, params) -> None:
        self._admit(params)
        self._decode_once(params)

    def run(self, params, *, max_steps: int = 10_000) -> None:
        steps = 0
        while self.pending and steps < max_steps:
            self.step(params)
            steps += 1

    @property
    def stats(self) -> Dict[str, Any]:
        return {
            "completed": len(self.completed),
            "shed": self.shed,
            "admission_max_batch": self.admission.max_batch_size,
            "decode_steps": self.decode_steps,
            "decode_host_syncs": self.decode_host_syncs,
            "host_syncs_per_decode_step": (
                self.decode_host_syncs / self.decode_steps
                if self.decode_steps else 0.0),
            "prefill_compiles": self.prefill_compiles,
            "prefill_dispatches": self.prefill_dispatches,
        }

    def engine_report(self) -> Dict[str, Any]:
        """Engine-level observability counters (DESIGN.md §11 hot path):
        where XLA compiles happened, how chatty the decode loop is with the
        host, and which attention backend the decode step traced with."""
        return {
            "fused": self.fused,
            "attention_backend": get_attention_backend(),
            "prefill": {
                "dispatches": self.prefill_dispatches,
                "compiled_shapes": self.prefill_compiles,
                # ladder rungs actually compiled: [batch, prompt_len, padded]
                "shapes": [list(k) for k in sorted(self._prefill_cache)],
                # dispatches per (padded) prompt length — rung utilization
                "rung_dispatches": {str(k): v for k, v in
                                    sorted(self.rung_dispatches.items())},
            },
            "decode": {
                "steps": self.decode_steps,
                "host_syncs": self.decode_host_syncs,
                "host_syncs_per_step": (
                    self.decode_host_syncs / self.decode_steps
                    if self.decode_steps else 0.0),
            },
        }

    def report(self) -> Dict[str, Any]:
        """Canonical telemetry report (metrics.py schema, shared with the
        Clipper frontend), plus the engine-level ``engine`` section; with a
        tracer attached it also gains ``latency_attribution`` and a
        ``trace`` summary (same contract as ``Clipper.report``)."""
        rep = self.metrics.report("lmserver")
        rep["engine"] = self.engine_report()
        if self.tracer is not None:
            rep["latency_attribution"] = self.tracer.attribution_report()
            rep["trace"] = self.tracer.summary()
        return rep

    def report_json(self, **extra: Any) -> str:
        rep = self.report()
        rep.update(extra)
        return json.dumps(rep, sort_keys=True, indent=2)


def _scatter_cache(cache, pcache, src: int, dst: int):
    """Copy request ``src`` of a prefill cache into slot ``dst`` (reference
    per-request path; the fused engine uses :func:`batched_scatter`)."""
    out = {}
    for k, v in cache.items():
        pv = pcache[k]
        if isinstance(v, tuple):
            out[k] = tuple(_scatter_leaf(a, b, src, dst) for a, b in zip(v, pv))
        else:
            out[k] = _scatter_leaf(v, pv, src, dst)
    return out


def _scatter_leaf(dst_arr, src_arr, src: int, dst: int):
    if dst_arr.ndim == 1:                   # lengths [B]
        return dst_arr.at[dst].set(src_arr[src])
    # layer-stacked [L, B, ...]: batch is dim 1
    sl = src_arr[:, src]
    if dst_arr.ndim > 2:
        pad = dst_arr.shape[2] - sl.shape[1]
        if pad > 0:
            sl = jnp.pad(sl, [(0, 0), (0, pad)] + [(0, 0)] * (sl.ndim - 2))
    return dst_arr.at[:, dst].set(sl.astype(dst_arr.dtype))
