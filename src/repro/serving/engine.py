"""LM serving engine: continuous batching with Clipper admission control.

Requests (token prompts) enter an AIMD-governed admission queue (paper §4.3
applied to prefill); admitted prompts are prefilled in bucket-padded batches
and parked in decode *slots*; every engine step advances all active slots by
one token through a single jitted decode step (continuous batching). Slot
caches live in one donated buffer, so decode never reallocates.

This is deliberately the same architecture a TPU pod would run — the jitted
prefill/decode functions come from launch/steps.py-style builders with the
production shardings; here they execute on the local mesh.

Telemetry note: in wall-clock mode the first dispatch of each shape bucket
includes XLA compilation in its measured service time — a real cold-start
the tail percentiles deliberately keep. Calibrated-simulation mode
(``service_model`` + ``VirtualClock``) has no such transient."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batching import AIMDController, bucket
from repro.core import metrics as M
from repro.core.metrics import MetricsRegistry
from repro.distributed.sharding import sharding_context
from repro.models.api import Model
from repro.serving.sampler import sample

# Calibrated-simulation hook (DESIGN.md §8): maps ("prefill", batch, tokens)
# or ("decode", batch, 1) to modeled service seconds, where batch is the
# *executed* shape (padded prefill bucket; all decode slots) — the shapes the
# wall-clock engine actually pays for. With one installed, the engine
# advances its (advanceable) clock by modeled time instead of measuring
# wall-clock — deterministic, byte-identical telemetry from a seed.
ServiceModel = Callable[[str, int, int], float]


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_time: float
    tokens: List[int] = field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None
    prefill_time: Optional[float] = None
    finish_time: Optional[float] = None


class LMServer:
    """Continuous-batching server for one Model."""

    def __init__(self, model: Model, mesh, rules, *, slots: int = 8,
                 max_len: int = 256, slo: float = 0.5,
                 temperature: float = 0.0, eos_token: int = -1,
                 seed: int = 0, clock: Callable[[], float] = time.perf_counter,
                 metrics: Optional[MetricsRegistry] = None,
                 service_model: Optional[ServiceModel] = None,
                 model_id: str = "lm", admission_control=None):
        self.model = model
        self.mesh = mesh
        self.rules = rules
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.eos = eos_token
        self.slo = slo
        self.clock = clock
        self.service_model = service_model
        if service_model is not None and not hasattr(clock, "advance"):
            # modeled service times with a wall clock would mix timelines:
            # service_s modeled, latencies/throughput wall-clock
            raise ValueError(
                "service_model requires an advanceable clock "
                "(e.g. metrics.VirtualClock) so the whole report shares "
                "one timeline")
        self.model_id = model_id
        self.metrics = metrics if metrics is not None else MetricsRegistry(slo)
        # SLO-aware admission control (repro.cluster.admission): consulted
        # per submit; rejected requests are shed before they touch the
        # queue. Distinct from ``self.admission``, the AIMD *batch-size*
        # controller that governs prefill admission below.
        self.admission_control = admission_control
        self.shed = 0
        self.admission = AIMDController(slo, additive=1, init=1,
                                        max_batch=slots)
        self.rng = jax.random.PRNGKey(seed)
        self._queue: List[Request] = []
        self._active: Dict[int, Request] = {}      # slot -> request
        self._next_id = 0
        self.completed: Dict[int, Request] = {}

        self.cache = model.init_cache(slots, max_len)
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.cur_tokens = jnp.zeros((slots, 1), jnp.int32)

        def decode_fn(params, cache, tokens, lengths, key):
            with sharding_context(mesh, rules):
                logits, cache = model.decode_step(params, cache, tokens, lengths)
            toks = sample(logits, key, temperature=temperature)
            return toks, cache

        self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self._prefill_cache: Dict[int, Any] = {}   # bucket -> jitted prefill

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               now: Optional[float] = None) -> int:
        """Enqueue a prompt. ``now`` (when given) must be on the same
        timeline as this server's ``clock`` — completion telemetry computes
        ``finish - arrival`` with ``clock()``, so a foreign timestamp (e.g.
        0.0 against the default wall clock) yields garbage latencies."""
        rid = self._next_id
        self._next_id += 1
        at = self.clock() if now is None else now
        self.metrics.inc(M.QUERIES_SUBMITTED)
        self.metrics.mark(at)
        if (self.admission_control is not None
                and not self.admission_control.admit_lm(self, at)):
            self.metrics.inc(M.QUERIES_SHED)
            self.shed += 1
            return rid              # shed — never queued, never completes
        self._queue.append(Request(rid, np.asarray(prompt, np.int32),
                                   max_new_tokens, at))
        return rid

    def est_request_service(self) -> float:
        """Observed engine-seconds per completed request — the backlog-drain
        estimate admission control consumes. Zero until the first completion
        (admit everything while there is no signal)."""
        done = self.metrics.counter(M.QUERIES_COMPLETED)
        h = self.metrics.hist(M.SERVICE, model=self.model_id)
        if not done or h is None:
            return 0.0
        return h.total / done

    def _service_time(self, kind: str, batch: int, tokens: int,
                      t0: float) -> float:
        """Measured wall-clock, or modeled time (advancing the injected
        clock) in calibrated-simulation mode."""
        if self.service_model is None:
            return self.clock() - t0
        dt = self.service_model(kind, batch, tokens)
        self.clock.advance(dt)      # ctor guarantees the clock is advanceable
        return dt

    def _prefill_jit(self, b: int, plen: int):
        key = (b, plen)
        if key not in self._prefill_cache:
            def fn(params, tokens):
                with sharding_context(self.mesh, self.rules):
                    return self.model.prefill(params, {"tokens": tokens},
                                              max_len=self.max_len)
            self._prefill_cache[key] = jax.jit(fn)
        return self._prefill_cache[key]

    def _admit(self, params) -> None:
        free = [s for s in range(self.slots) if s not in self._active]
        if not free or not self._queue:
            return
        n = min(len(free), len(self._queue), self.admission.max_batch_size)
        # admit a same-length group (prefill has no per-sample prompt masking;
        # grouping by length avoids junk-token attention)
        plen = len(self._queue[0].prompt)
        batch = []
        for r in list(self._queue):
            if len(r.prompt) == plen and len(batch) < n:
                batch.append(r)
                self._queue.remove(r)
        n = len(batch)
        if n == 0:
            return
        self.metrics.observe(M.QUEUE_DEPTH, n + len(self._queue))
        nb = bucket(n, cap=self.slots)
        toks = np.zeros((nb, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i] = r.prompt
        t0 = self.clock()
        logits, pcache = self._prefill_jit(nb, plen)(
            params, jnp.asarray(toks))
        jax.block_until_ready(logits)
        # the service model is charged the *executed* shape (padded bucket),
        # matching what wall-clock mode measures for the same workload
        dt = self._service_time("prefill", nb, plen, t0)
        self.admission.record(n, dt)
        self.metrics.inc(M.QUERIES_SUBMITTED, n, model=self.model_id)
        self._observe_batch(n, dt)
        self.metrics.mark(self.clock())
        self.rng, k = jax.random.split(self.rng)
        first = sample(logits, k, temperature=self.temperature)
        first = np.asarray(first)
        # scatter prefilled caches into decode slots
        for i, r in enumerate(batch):
            s = free[i]
            r.slot = s
            r.prefill_time = dt
            r.tokens.append(int(first[i]))
            self._active[s] = r
            self.cache = _scatter_cache(self.cache, pcache, i, s)
            self.lengths = self.lengths.at[s].set(plen)
            self.cur_tokens = self.cur_tokens.at[s, 0].set(int(first[i]))

    def _decode_once(self, params) -> None:
        if not self._active:
            return
        t0 = self.clock()
        self.rng, k = jax.random.split(self.rng)
        toks, self.cache = self._decode(params, self.cache, self.cur_tokens,
                                        self.lengths, k)
        toks = np.asarray(toks)
        n_active = len(self._active)
        # executed shape: the jitted decode computes every slot each step
        # regardless of how many are active, like the wall-clock engine
        dt = self._service_time("decode", self.slots, 1, t0)
        # decode steps dominate LM serving work — they count as dispatched
        # batches alongside prefill, so the report reflects the whole run
        self._observe_batch(n_active, dt)
        self.lengths = self.lengths + jnp.asarray(
            [1 if s in self._active else 0 for s in range(self.slots)],
            jnp.int32)
        for s, r in list(self._active.items()):
            t = int(toks[s])
            r.tokens.append(t)
            self.cur_tokens = self.cur_tokens.at[s, 0].set(t)
            if (t == self.eos or len(r.tokens) >= r.max_new_tokens
                    or int(self.lengths[s]) >= self.max_len - 1):
                r.done = True
                r.finish_time = self.clock()
                self.completed[r.request_id] = r
                del self._active[s]
                self.metrics.inc(M.QUERIES_COMPLETED)
                self.metrics.observe_latency(r.finish_time - r.arrival_time)
                self.metrics.mark(r.finish_time)

    def _observe_batch(self, size: int, service: float) -> None:
        """One dispatched batch (prefill or decode) into the shared schema —
        both dispatch paths must stay in lockstep."""
        self.metrics.inc_both(M.BATCHES, model=self.model_id)
        self.metrics.observe_both(M.BATCH_SIZE, size, model=self.model_id)
        self.metrics.observe_both(M.SERVICE, service, model=self.model_id)

    @property
    def pending(self) -> bool:
        """True while any request is queued or decoding — the public drive
        predicate (ScenarioRunner and external loops use this, not the
        private queue/slot state)."""
        return bool(self._queue or self._active)

    def step(self, params) -> None:
        self._admit(params)
        self._decode_once(params)

    def run(self, params, *, max_steps: int = 10_000) -> None:
        steps = 0
        while self.pending and steps < max_steps:
            self.step(params)
            steps += 1

    @property
    def stats(self) -> Dict[str, Any]:
        return {
            "completed": len(self.completed),
            "shed": self.shed,
            "admission_max_batch": self.admission.max_batch_size,
        }

    def report(self) -> Dict[str, Any]:
        """Canonical telemetry report (metrics.py schema, shared with the
        Clipper frontend)."""
        return self.metrics.report("lmserver")

    def report_json(self, **extra: Any) -> str:
        return self.metrics.report_json("lmserver", **extra)


def _scatter_cache(cache, pcache, src: int, dst: int):
    """Copy request ``src`` of a prefill cache into slot ``dst``."""
    out = {}
    for k, v in cache.items():
        pv = pcache[k]
        if isinstance(v, tuple):
            out[k] = tuple(_scatter_leaf(a, b, src, dst) for a, b in zip(v, pv))
        else:
            out[k] = _scatter_leaf(v, pv, src, dst)
    return out


def _scatter_leaf(dst_arr, src_arr, src: int, dst: int):
    if dst_arr.ndim == 1:                   # lengths [B]
        return dst_arr.at[dst].set(src_arr[src])
    # layer-stacked [L, B, ...]: batch is dim 1
    sl = src_arr[:, src]
    if dst_arr.ndim > 2:
        pad = dst_arr.shape[2] - sl.shape[1]
        if pad > 0:
            sl = jnp.pad(sl, [(0, 0), (0, pad)] + [(0, 0)] * (sl.ndim - 2))
    return dst_arr.at[:, dst].set(sl.astype(dst_arr.dtype))
