"""LM serving engine: continuous batching with Clipper admission control.

Requests (token prompts) enter an AIMD-governed admission queue (paper §4.3
applied to prefill); admitted prompts are prefilled in bucket-padded batches
and parked in decode *slots*; every engine step advances all active slots by
one token through a single jitted decode step (continuous batching). Slot
caches live in one donated buffer, so decode never reallocates.

This is deliberately the same architecture a TPU pod would run — the jitted
prefill/decode functions come from launch/steps.py-style builders with the
production shardings; here they execute on the local mesh."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batching import AIMDController, bucket
from repro.distributed.sharding import sharding_context
from repro.models.api import Model
from repro.serving.sampler import sample


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_time: float
    tokens: List[int] = field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None
    prefill_time: Optional[float] = None
    finish_time: Optional[float] = None


class LMServer:
    """Continuous-batching server for one Model."""

    def __init__(self, model: Model, mesh, rules, *, slots: int = 8,
                 max_len: int = 256, slo: float = 0.5,
                 temperature: float = 0.0, eos_token: int = -1,
                 seed: int = 0):
        self.model = model
        self.mesh = mesh
        self.rules = rules
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.eos = eos_token
        self.admission = AIMDController(slo, additive=1, init=1,
                                        max_batch=slots)
        self.rng = jax.random.PRNGKey(seed)
        self._queue: List[Request] = []
        self._active: Dict[int, Request] = {}      # slot -> request
        self._next_id = 0
        self.completed: Dict[int, Request] = {}

        self.cache = model.init_cache(slots, max_len)
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.cur_tokens = jnp.zeros((slots, 1), jnp.int32)

        def decode_fn(params, cache, tokens, lengths, key):
            with sharding_context(mesh, rules):
                logits, cache = model.decode_step(params, cache, tokens, lengths)
            toks = sample(logits, key, temperature=temperature)
            return toks, cache

        self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self._prefill_cache: Dict[int, Any] = {}   # bucket -> jitted prefill

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               now: Optional[float] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append(Request(rid, np.asarray(prompt, np.int32),
                                   max_new_tokens,
                                   time.perf_counter() if now is None else now))
        return rid

    def _prefill_jit(self, b: int, plen: int):
        key = (b, plen)
        if key not in self._prefill_cache:
            def fn(params, tokens):
                with sharding_context(self.mesh, self.rules):
                    return self.model.prefill(params, {"tokens": tokens},
                                              max_len=self.max_len)
            self._prefill_cache[key] = jax.jit(fn)
        return self._prefill_cache[key]

    def _admit(self, params) -> None:
        free = [s for s in range(self.slots) if s not in self._active]
        if not free or not self._queue:
            return
        n = min(len(free), len(self._queue), self.admission.max_batch_size)
        # admit a same-length group (prefill has no per-sample prompt masking;
        # grouping by length avoids junk-token attention)
        plen = len(self._queue[0].prompt)
        batch = []
        for r in list(self._queue):
            if len(r.prompt) == plen and len(batch) < n:
                batch.append(r)
                self._queue.remove(r)
        n = len(batch)
        nb = bucket(n, cap=self.slots)
        toks = np.zeros((nb, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i] = r.prompt
        t0 = time.perf_counter()
        logits, pcache = self._prefill_jit(nb, plen)(
            params, jnp.asarray(toks))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.admission.record(n, dt)
        self.rng, k = jax.random.split(self.rng)
        first = sample(logits, k, temperature=self.temperature)
        first = np.asarray(first)
        # scatter prefilled caches into decode slots
        for i, r in enumerate(batch):
            s = free[i]
            r.slot = s
            r.prefill_time = dt
            r.tokens.append(int(first[i]))
            self._active[s] = r
            self.cache = _scatter_cache(self.cache, pcache, i, s)
            self.lengths = self.lengths.at[s].set(plen)
            self.cur_tokens = self.cur_tokens.at[s, 0].set(int(first[i]))

    def _decode_once(self, params) -> None:
        if not self._active:
            return
        self.rng, k = jax.random.split(self.rng)
        toks, self.cache = self._decode(params, self.cache, self.cur_tokens,
                                        self.lengths, k)
        toks = np.asarray(toks)
        self.lengths = self.lengths + jnp.asarray(
            [1 if s in self._active else 0 for s in range(self.slots)],
            jnp.int32)
        for s, r in list(self._active.items()):
            t = int(toks[s])
            r.tokens.append(t)
            self.cur_tokens = self.cur_tokens.at[s, 0].set(t)
            if (t == self.eos or len(r.tokens) >= r.max_new_tokens
                    or int(self.lengths[s]) >= self.max_len - 1):
                r.done = True
                r.finish_time = time.perf_counter()
                self.completed[r.request_id] = r
                del self._active[s]

    def step(self, params) -> None:
        self._admit(params)
        self._decode_once(params)

    def run(self, params, *, max_steps: int = 10_000) -> None:
        steps = 0
        while (self._queue or self._active) and steps < max_steps:
            self.step(params)
            steps += 1

    @property
    def stats(self) -> Dict[str, Any]:
        return {
            "completed": len(self.completed),
            "admission_max_batch": self.admission.max_batch_size,
        }


def _scatter_cache(cache, pcache, src: int, dst: int):
    """Copy request ``src`` of a prefill cache into slot ``dst``."""
    out = {}
    for k, v in cache.items():
        pv = pcache[k]
        if isinstance(v, tuple):
            out[k] = tuple(_scatter_leaf(a, b, src, dst) for a, b in zip(v, pv))
        else:
            out[k] = _scatter_leaf(v, pv, src, dst)
    return out


def _scatter_leaf(dst_arr, src_arr, src: int, dst: int):
    if dst_arr.ndim == 1:                   # lengths [B]
        return dst_arr.at[dst].set(src_arr[src])
    # layer-stacked [L, B, ...]: batch is dim 1
    sl = src_arr[:, src]
    if dst_arr.ndim > 2:
        pad = dst_arr.shape[2] - sl.shape[1]
        if pad > 0:
            sl = jnp.pad(sl, [(0, 0), (0, pad)] + [(0, 0)] * (sl.ndim - 2))
    return dst_arr.at[:, dst].set(sl.astype(dst_arr.dtype))
