"""Token sampling for the serving engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, rng_key, *, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    """logits: [B, V] -> tokens [B]. temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        thresh = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < thresh, -jnp.inf, logits)
    return jax.random.categorical(rng_key, logits).astype(jnp.int32)
