"""Optimizers built from scratch (no optax in this environment).

AdamW keeps fp32 moments + fp32 master weights for bf16 params (mixed
precision); Adafactor offers the low-memory alternative for the 1T-param
config. State trees mirror the param tree so the same logical-axes sharding
applies (DESIGN.md §6)."""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any          # fp32 master copy of params (None if params fp32)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros), master=master)


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    step = state.step + 1
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)) + 1e-12)
    scale = jnp.minimum(1.0, grad_clip / gnorm)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        w = w - lr * (u + weight_decay * w)
        return m, v, w

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_w = treedef.flatten_up_to(state.master)
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    m = jax.tree.unflatten(treedef, [o[0] for o in out])
    v = jax.tree.unflatten(treedef, [o[1] for o in out])
    master = jax.tree.unflatten(treedef, [o[2] for o in out])
    old_flat = treedef.flatten_up_to(params)
    new_params = jax.tree.unflatten(
        treedef, [w.astype(p.dtype) for w, p in zip([o[2] for o in out], old_flat)])
    return new_params, AdamWState(step, m, v, master), gnorm


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any              # row second-moment (or full v for <2D tensors)
    vc: Any              # col second-moment


def adafactor_init(params) -> AdafactorState:
    def rows(p):
        if p.ndim < 2:
            return jnp.zeros(p.shape, jnp.float32)
        return jnp.zeros(p.shape[:-1], jnp.float32)

    def cols(p):
        if p.ndim < 2:
            return jnp.zeros((), jnp.float32)
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(rows, params),
                          vc=jax.tree.map(cols, params))


def adafactor_update(grads, state: AdafactorState, params, *, lr,
                     decay=0.8, eps=1e-30, clip=1.0):
    step = state.step + 1
    beta = 1.0 - step.astype(jnp.float32) ** -decay

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if p.ndim < 2:
            vr = beta * vr + (1 - beta) * g2
            u = g / jnp.sqrt(vr)
        else:
            vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
            vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
            r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
            u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :])
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip)
        return vr, vc, (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_vr = treedef.flatten_up_to(state.vr)
    flat_vc = treedef.flatten_up_to(state.vc)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, vr, vc, p) for g, vr, vc, p in
           zip(flat_g, flat_vr, flat_vc, flat_p)]
    vr = jax.tree.unflatten(treedef, [o[0] for o in out])
    vc = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_params = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, AdafactorState(step, vr, vc), jnp.float32(0.0)


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


def opt_state_axes(opt_state, param_axes):
    """Logical axes for optimizer state (mirrors params; scalars -> ())."""
    if isinstance(opt_state, AdamWState):
        return AdamWState(step=(), m=param_axes,
                          v=param_axes, master=param_axes)
    if isinstance(opt_state, AdafactorState):
        def drop_last(axes):
            return axes[:-1] if len(axes) >= 2 else axes
        def drop_2nd_last(axes):
            return (axes[:-2] + axes[-1:]) if len(axes) >= 2 else ()
        is_axes = lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)
        vr = jax.tree.map(drop_last, param_axes, is_leaf=is_axes)
        vc = jax.tree.map(drop_2nd_last, param_axes, is_leaf=is_axes)
        return AdafactorState(step=(), vr=vr, vc=vc)
    raise TypeError(type(opt_state))
