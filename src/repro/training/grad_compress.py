"""Gradient computation with microbatch accumulation and (optionally)
int8-quantized cross-pod reduction.

Within a pod, gradients reduce through XLA's normal sharding propagation
(reduce-scatter/all-reduce over the ``data`` axis). *Across pods* — the slow
inter-pod links — per-pod gradients are computed with
``jax.vmap(..., spmd_axis_name="pod")`` over an explicit pod dimension, so
autodiff never inserts its own fp32 pod all-reduce; the stacked gradients
are then quantized and summed over the pod axis:

    scale = max|g| / 127                  (per tensor, scalar collective)
    q     = round(g / scale)    : int8
    sum   = Σ_pods int16(q)               (int16 on the wire: exact for
                                           <= 256 pods, 2x fewer bytes
                                           than the fp32 baseline)
    g     = sum * scale / n_pods

The int16 wire format is visible in the compiled HLO (s16 all-reduce over
the pod replica groups) and its collective-term effect is recorded in
EXPERIMENTS.md §Perf."""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P


def _accumulate(loss_fn: Callable, params, batch, num_microbatches: int):
    """Gradient accumulation over microbatches (fp32 accumulators)."""
    if num_microbatches <= 1:
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        return loss, jax.tree.map(lambda x: x.astype(jnp.float32), g)

    mbs = jax.tree.map(
        lambda x: x.reshape((num_microbatches, x.shape[0] // num_microbatches)
                            + x.shape[1:]), batch)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        acc_loss, acc_g = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        acc_g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc_g, g)
        return (acc_loss + loss, acc_g), None

    (loss, g), _ = lax.scan(body, (jnp.float32(0.0), zeros), mbs)
    inv = 1.0 / num_microbatches
    return loss * inv, jax.tree.map(lambda x: x * inv, g)


def _quantized_pod_mean(g: jax.Array) -> jax.Array:
    """g: [npods, ...] (dim 0 sharded over pod) -> mean over pods, int8
    payload / int16 accumulator on the inter-pod wire."""
    npods = g.shape[0]
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))                         # scalar collective
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    # dtype pinned: some JAX versions promote int16 sums to int32, which
    # would silently double the wire bytes (and break the HLO s16 check)
    total = jnp.sum(q.astype(jnp.int16), axis=0, dtype=jnp.int16)
    return total.astype(jnp.float32) * (scale / npods)


def loss_and_grads(loss_fn: Callable, params, batch, mesh, *,
                   num_microbatches: int = 1,
                   pod_compress: bool = True) -> Tuple[jax.Array, Any]:
    """Returns (loss, fp32 grads), pod-reduced (compressed when enabled)."""
    multi_pod = "pod" in mesh.shape and mesh.shape["pod"] > 1
    if not multi_pod:
        return _accumulate(loss_fn, params, batch, num_microbatches)

    npods = mesh.shape["pod"]

    def fold(x):
        x = x.reshape((npods, x.shape[0] // npods) + x.shape[1:])
        spec = P("pod", "data", *([P.UNCONSTRAINED] * (x.ndim - 2)))
        return lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    batch_p = jax.tree.map(fold, batch)
    per_pod = lambda b: _accumulate(loss_fn, params, b, num_microbatches)
    losses, grads = jax.vmap(per_pod, spmd_axis_name="pod")(batch_p)

    def stack_spec(g):
        return lax.with_sharding_constraint(
            g, NamedSharding(mesh, P("pod", *([P.UNCONSTRAINED] * (g.ndim - 1)))))

    grads = jax.tree.map(stack_spec, grads)
    if pod_compress:
        grads = jax.tree.map(_quantized_pod_mean, grads)
    else:
        grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
    return jnp.mean(losses), grads
