"""Training loop substrate: step factory + fault-tolerant driver.

``make_train_step`` builds the jitted SPMD step used by both the real
trainer and the multi-pod dry-run (identical code path — the dry-run just
lowers it against ShapeDtypeStructs). The driver adds checkpoint/restart
(elastic re-shard on load), periodic eval, and NaN-step skipping."""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (
    ShardingContext, params_shardings, sharding_context,
)
from repro.launch.inputs import batch_axes_tree
from repro.training import optimizer as opt_lib
from repro.training.grad_compress import loss_and_grads


@dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    num_microbatches: int = 1
    optimizer: str = "adamw"          # adamw | adafactor
    pod_compress: bool = True
    skip_nan_steps: bool = True


def make_train_step(model, mesh, rules, tc: TrainConfig):
    """Returns (train_step, init_opt_state, shardings dict)."""
    ctx = ShardingContext(mesh, rules)
    schedule = opt_lib.cosine_schedule(tc.lr, tc.warmup_steps, tc.total_steps)

    if tc.optimizer == "adamw":
        opt_init, opt_update = opt_lib.adamw_init, partial(
            opt_lib.adamw_update, weight_decay=tc.weight_decay,
            grad_clip=tc.grad_clip)
    else:
        opt_init, opt_update = opt_lib.adafactor_init, opt_lib.adafactor_update

    def train_step(params, opt_state, batch):
        with sharding_context(mesh, rules):
            loss, grads = loss_and_grads(
                model.loss_fn, params, batch, mesh,
                num_microbatches=tc.num_microbatches,
                pod_compress=tc.pod_compress)
            lr = schedule(opt_state.step)
            new_params, new_opt, gnorm = opt_update(grads, opt_state, params,
                                                    lr=lr)
            if tc.skip_nan_steps:
                ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
                new_params = jax.tree.map(
                    lambda n, o: jnp.where(ok, n, o), new_params, params)
                new_opt = jax.tree.map(
                    lambda n, o: jnp.where(ok, n, o), new_opt, opt_state)
            return new_params, new_opt, {"loss": loss, "gnorm": gnorm, "lr": lr}

    param_sh = params_shardings(model.param_axes, ctx)
    opt_axes_fn = opt_lib.opt_state_axes

    def shardings_for(opt_state_shape):
        opt_axes = opt_axes_fn(opt_state_shape, model.param_axes)
        return {
            "params": param_sh,
            "opt": params_shardings(opt_axes, ctx),
        }

    return train_step, opt_init, shardings_for


def jit_train_step(model, mesh, rules, tc: TrainConfig, batch_specs,
                   batch_rules=None):
    """Fully-specified jit of the train step (used by trainer and dry-run).

    ``rules`` govern the model internals (pod-free under pod compression);
    ``batch_rules`` govern how the global batch arrives (may include pod)."""
    ctx = ShardingContext(mesh, rules)
    ctx_batch = ShardingContext(mesh, batch_rules or rules)
    train_step, opt_init, shardings_for = make_train_step(model, mesh, rules, tc)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(opt_init, params_shape)
    sh = shardings_for(opt_shape)
    batch_axes = batch_axes_tree(batch_specs)
    batch_sh = jax.tree.map(
        lambda ax: ctx_batch.sharding(ax), batch_axes,
        is_leaf=lambda x: isinstance(x, tuple))
    metrics_sh = {"loss": ctx.sharding(()), "gnorm": ctx.sharding(()),
                  "lr": ctx.sharding(())}
    step = jax.jit(
        train_step,
        in_shardings=(sh["params"], sh["opt"], batch_sh),
        out_shardings=(sh["params"], sh["opt"], metrics_sh),
        donate_argnums=(0, 1),
    )
    return step, opt_init, sh, batch_sh


def train(model, mesh, rules, tc: TrainConfig, data_iter, *,
          num_steps: int, checkpoint_dir: Optional[str] = None,
          checkpoint_every: int = 100, resume: bool = True,
          log_every: int = 10, rng_seed: int = 0,
          hooks: Optional[Dict[str, Callable]] = None) -> Dict[str, Any]:
    """Fault-tolerant training driver (checkpoint/restart, elastic reshard)."""
    from repro.checkpoint.checkpointer import Checkpointer

    ctx = ShardingContext(mesh, rules)
    first = next(data_iter)
    batch_specs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), first)
    step_fn, opt_init, sh, batch_sh = jit_train_step(
        model, mesh, rules, tc, batch_specs)

    start_step = 0
    ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
    if ckpt and resume and ckpt.latest_step() is not None:
        start_step = ckpt.latest_step()
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(rng_seed))
        params = ckpt.restore(start_step, "params", params_shape, sh["params"])
        opt_state = ckpt.restore(
            start_step, "opt", jax.eval_shape(opt_init, params_shape),
            sh["opt"])
    else:
        with sharding_context(mesh, rules):
            params = jax.jit(model.init, out_shardings=sh["params"])(
                jax.random.PRNGKey(rng_seed))
            opt_state = jax.jit(opt_init, out_shardings=sh["opt"])(params)

    history = []
    batch = first
    for i in range(start_step, num_steps):
        batch_dev = jax.device_put(batch, batch_sh)
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        if (i + 1) % log_every == 0 or i == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i + 1
            history.append(m)
            if hooks and "on_log" in hooks:
                hooks["on_log"](m)
        if ckpt and ((i + 1) % checkpoint_every == 0 or i == num_steps - 1):
            ckpt.save(i + 1, {"params": params, "opt": opt_state})
        try:
            batch = next(data_iter)
        except StopIteration:
            break
    return {"params": params, "opt_state": opt_state, "history": history}
