"""dbrx-132b — fine-grained 16-expert top-4 MoE.
[hf:databricks/dbrx-base; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    num_experts_per_tok=4,
    source="hf:databricks/dbrx-base; unverified",
)
