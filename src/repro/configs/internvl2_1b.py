"""internvl2-1b — InternViT + InternLM2; vision frontend is a STUB supplying
precomputed patch embeddings (DESIGN.md §4). [arXiv:2404.16821; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    frontend="vision",
    num_prefix_embeddings=1024,   # ViT patch tokens prepended to text
    source="arXiv:2404.16821; hf",
)
