"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, SHAPES_BY_NAME, ShapeSpec, applicable_shapes

from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.xlstm_125m import CONFIG as _xlstm
from repro.configs.granite_8b import CONFIG as _granite
from repro.configs.qwen2_7b import CONFIG as _qwen2
from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.minitron_8b import CONFIG as _minitron
from repro.configs.internvl2_1b import CONFIG as _internvl2
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.hymba_1_5b import CONFIG as _hymba

ARCHITECTURES: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _dbrx, _kimi, _xlstm, _granite, _qwen2,
        _smollm, _minitron, _internvl2, _seamless, _hymba,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[name]


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES_BY_NAME:
        raise KeyError(f"unknown shape {name!r}; choose from {sorted(SHAPES_BY_NAME)}")
    return SHAPES_BY_NAME[name]


def reduced_config(cfg: ModelConfig, *, num_layers: int = 2, d_model: int = 64,
                   vocab_size: int = 256) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (DESIGN.md §4).

    Keeps the family, attention grouping ratios and block structure; shrinks
    widths, depth, expert count, and embedding tables.
    """
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, 2))
    while heads % kv:
        heads += 1
    updates = dict(
        num_layers=num_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=0 if cfg.d_ff == 0 else d_model * 3,
        vocab_size=vocab_size,
        num_prefix_embeddings=8 if cfg.num_prefix_embeddings else 0,
        window=16 if cfg.window else 0,
        global_layers=(0,) if cfg.global_layers else (),
        ssm_state=8 if cfg.ssm_state else 0,
    )
    if cfg.num_experts:
        updates.update(num_experts=4, num_experts_per_tok=2)
    return dataclasses.replace(cfg, **updates)


def all_cells():
    """Every runnable (arch, shape) pair — 32 cells (DESIGN.md §4)."""
    for name, cfg in sorted(ARCHITECTURES.items()):
        for shape in applicable_shapes(cfg):
            yield cfg, shape
