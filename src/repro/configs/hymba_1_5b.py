"""hymba-1.5b — hybrid: parallel attention + mamba heads per block; 3 global
attention layers, the rest sliding-window. [arXiv:2411.13676; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    window=2048,
    global_layers=(0, 15, 31),
    source="arXiv:2411.13676; hf",
)
