"""xlstm-125m — sLSTM + mLSTM blocks (pair-scanned, see DESIGN.md §4).
[arXiv:2405.04517; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,              # block-internal projections replace the FFN
    vocab_size=50304,
    source="arXiv:2405.04517; unverified",
)
