"""Model and shape configuration for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig` holding the
*logical* (published) dimensions.  Sharding-time padding (TP divisibility for
heads / vocab) is derived via :meth:`ModelConfig.padded` and never mutates the
logical config — see DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class PaddedDims:
    """TP-divisible dimensions derived from a logical config for a given tp."""

    num_q_heads: int
    num_kv_heads: int
    q_group: int          # q heads per kv head after padding
    vocab_size: int
    head_dim: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int = 0                  # sliding window size; 0 = full attention
    global_layers: Tuple[int, ...] = ()  # layer indices forced to full attention

    # ssm / hybrid
    ssm_state: int = 0
    conv_width: int = 4

    # modality frontend stubs (DESIGN.md §4): embeddings are inputs
    frontend: Optional[str] = None   # 'vision' | 'audio'
    num_prefix_embeddings: int = 0   # e.g. vision patch tokens

    # encoder-decoder
    is_encoder_decoder: bool = False
    decoder_ratio: int = 8           # decoder_len = seq_len // decoder_ratio

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""                 # provenance tag from the assignment

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def is_full_attention(self) -> bool:
        """True when *every* token attends to the whole prefix (no recurrent or
        windowed bound) — such archs skip long_500k per the assignment."""
        return self.family in ("dense", "moe", "vlm", "encdec")

    @property
    def has_decode_step(self) -> bool:
        return True  # all assigned archs have a decoder (enc-dec included)

    def padded(self, tp: int) -> PaddedDims:
        """TP-divisible head/vocab padding (DESIGN.md §4).

        - q heads are padded up to a multiple of tp,
        - kv heads are padded/replicated up to ``min`` multiple of tp that also
          divides the padded q count evenly (so per-device GQA grouping works),
        - vocab is padded to a multiple of max(256, tp).
        """
        hd = self.resolved_head_dim
        nq = _round_up(self.num_heads, tp)
        nkv = self.num_kv_heads
        if nkv % tp != 0 and tp % nkv != 0:
            nkv = tp
        nkv = max(nkv, tp) if nkv < tp else nkv
        # ensure padded q divides evenly into kv groups
        nq = _round_up(nq, nkv) if nq % nkv else nq
        vocab = _round_up(self.vocab_size, max(256, tp))
        return PaddedDims(
            num_q_heads=nq,
            num_kv_heads=nkv,
            q_group=nq // nkv,
            vocab_size=vocab,
            head_dim=hd,
        )

    # ----- analytic parameter counts (logical dims) -----
    def param_count(self, padded_tp: int = 1) -> int:
        """Total parameter count. With padded_tp>1, counts the padded tensors
        actually allocated when sharded tp-ways."""
        p = self.padded(padded_tp)
        hd = p.head_dim
        nq, nkv, v = p.num_q_heads, p.num_kv_heads, p.vocab_size
        if padded_tp == 1:
            nq, nkv, v = self.num_heads, self.num_kv_heads, self.vocab_size
        d = self.d_model
        embed = v * d
        lm_head = 0 if self.tie_embeddings else v * d

        def attn_params() -> int:
            n = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
            if self.qkv_bias:
                n += (nq + 2 * nkv) * hd
            return n

        def dense_ffn() -> int:
            return 3 * d * self.d_ff  # gated GLU: up, gate, down

        def moe_ffn() -> int:
            return self.num_experts * 3 * d * self.d_ff + d * self.num_experts

        def ssm_params() -> int:
            # mamba2-style: in_proj (x,z,B,C,dt) + conv + out_proj
            d_inner = 2 * d
            return (d * (2 * d_inner + 2 * self.ssm_state * max(1, self.num_heads)
                         + max(1, self.num_heads))
                    + d_inner * self.conv_width + d_inner * d)

        per_layer = 2 * d  # norms
        if self.family in ("dense", "vlm"):
            per_layer += attn_params() + dense_ffn()
        elif self.family == "moe":
            per_layer += attn_params() + moe_ffn()
        elif self.family == "ssm":
            # xlstm pair block: mLSTM (qkv-style matrix memory, proj 2x) + sLSTM
            d_in = 2 * d
            mlstm = d * d_in * 2 + d_in * d + 3 * d_in * hd + 4 * d_in
            slstm = 4 * d * d + 4 * d + d * d
            per_layer += (mlstm + slstm) // 2  # averaged per layer (pair-scan)
        elif self.family == "hybrid":
            per_layer += attn_params() + dense_ffn() + ssm_params()
        elif self.family == "encdec":
            # enc layer: attn + ffn; dec layer: self + cross + ffn → average
            per_layer += attn_params() + dense_ffn() + (attn_params() + 2 * d) // 2
        return embed + lm_head + self.num_layers * per_layer

    def active_param_count(self) -> int:
        """Active parameters per token (== param_count for non-MoE)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        moe_active = self.num_experts_per_tok * 3 * d * self.d_ff
        moe_total = self.num_experts * 3 * d * self.d_ff
        return self.param_count() - self.num_layers * (moe_total - moe_active)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeSpec, ...]:
    """long_500k only runs for sub-quadratic archs (DESIGN.md §4)."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and cfg.is_full_attention:
            continue
        out.append(s)
    return tuple(out)


def suggest_microbatches(cfg: ModelConfig, shape: ShapeSpec, num_data_shards: int,
                         act_budget_bytes: float = 2e9) -> int:
    """Pick a gradient-accumulation factor so saved activations (block inputs
    under full remat) stay under the budget per device."""
    if shape.kind != "train":
        return 1
    local_batch = max(1, shape.global_batch // num_data_shards)
    per_sample = cfg.num_layers * shape.seq_len * cfg.d_model * 2  # bf16 block inputs
    max_mb_size = max(1, int(act_budget_bytes // max(1, per_sample)))
    mb_size = min(local_batch, max_mb_size)
    num_mb = max(1, local_batch // max(1, mb_size))
    while local_batch % num_mb:
        num_mb += 1
    return num_mb
