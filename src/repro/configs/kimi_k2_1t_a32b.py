"""kimi-k2-1t-a32b — trillion-param fine-grained MoE (384 experts, top-8).
[arXiv:2501.kimi2; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    num_experts_per_tok=8,
    source="arXiv:2501.kimi2; unverified",
)
