"""seamless-m4t-medium — encoder-decoder; audio frontend is a STUB supplying
precomputed frame embeddings (DESIGN.md §4). [arXiv:2308.11596; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,               # per stack: 12 encoder + 12 decoder
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    is_encoder_decoder=True,
    frontend="audio",
    decoder_ratio=8,             # decoder_len = seq_len // 8 (DESIGN.md §4)
    source="arXiv:2308.11596; hf",
)
