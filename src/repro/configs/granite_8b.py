"""granite-8b — llama-arch dense code model. [arXiv:2405.04324; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    source="arXiv:2405.04324; hf",
)
