"""Pure-jnp oracle for decode_attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, lengths, *, window: int = 0,
                         scale=None):
    """q: [B, Hq, D]; k/v_cache: [B, Hkv, Smax, D]; lengths: [B] ->
    [B, Hq, D]."""
    B, Hq, D = q.shape
    Hkv, Smax = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale or 1.0 / math.sqrt(D)
    k = jnp.repeat(k_cache, G, axis=1)
    v = jnp.repeat(v_cache, G, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    cols = jnp.arange(Smax)[None, None, :]
    mask = cols < lengths[:, None, None]
    if window > 0:
        mask &= cols >= (lengths[:, None, None] - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
