"""Jitted public wrapper for the decode attention kernel (model layout)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention


@functools.partial(jax.jit, static_argnames=("window", "k_blk", "interpret"))
def decode_attention_op(q, k_cache, v_cache, lengths, *, window: int = 0,
                        k_blk: int = 256, interpret: bool = False):
    """q: [B, 1, Hq, D]; k/v_cache: [B, Smax, Hkv, D]; lengths: [B] ->
    [B, 1, Hq, D] (matches repro.models.common.attention_decode)."""
    B, _, Hq, D = q.shape
    o = decode_attention(q[:, 0], jnp.swapaxes(k_cache, 1, 2),
                         jnp.swapaxes(v_cache, 1, 2), lengths,
                         window=window, k_blk=k_blk, interpret=interpret)
    return o[:, None]
