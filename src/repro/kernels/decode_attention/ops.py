"""Jitted public wrapper for the decode attention kernel (model layout).

The kernel consumes ``[B, Smax, Hkv, D]`` caches directly, so this wrapper
is copy-free: no per-call ``swapaxes`` relayout of the (large) KV cache —
only the (tiny) query is reshaped, which XLA folds into the kernel call."""

from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.decode_attention import decode_attention


@functools.partial(jax.jit, static_argnames=("window", "k_blk", "interpret"))
def decode_attention_op(q, k_cache, v_cache, lengths, *, window: int = 0,
                        k_blk: int = 256, interpret: bool = False):
    """q: [B, 1, Hq, D]; k/v_cache: [B, Smax, Hkv, D]; lengths: [B] ->
    [B, 1, Hq, D] (matches repro.models.common.attention_decode)."""
    o = decode_attention(q[:, 0], k_cache, v_cache, lengths,
                         window=window, k_blk=k_blk, interpret=interpret)
    return o[:, None]
