"""Pallas TPU decode attention (single-token GQA vs a long KV cache).

The decode hot loop is memory-bound: one query token must stream the whole
(per-sample) KV cache from HBM once. Grid (B, Hkv, nK): all G query heads
sharing a kv head are processed together as a [G, D] block so each K/V tile
is read exactly once per kv head (the GQA bandwidth win). Per-sample valid
lengths arrive via scalar prefetch (SMEM) and mask the tail tile.

The kernel consumes the caches in the *model layout* ``[B, Smax, Hkv, D]``
directly — the BlockSpec index maps slice ``(1, k_blk, 1, D)`` tiles
straight out of the cache, so no host-side ``swapaxes`` relayout copy is
paid per call (the serving engine calls this every decode step)."""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, k_blk: int, nk: int, window: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    k_lo = ki * k_blk
    live = k_lo < length
    if window > 0:
        live = live & (k_lo + k_blk > length - window)

    @pl.when(live)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)             # [k_blk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [G, kb]
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < length
        if window > 0:
            mask &= cols >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        v = v_ref[0, :, 0].astype(jnp.float32)             # [k_blk, D]
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, window: int = 0,
                     k_blk: int = 256, scale=None, interpret: bool = False):
    """q: [B, Hq, D]; k/v_cache: [B, Smax, Hkv, D] (model layout); lengths:
    [B] -> [B, Hq, D]."""
    B, Hq, D = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    k_blk = min(k_blk, Smax)
    assert Smax % k_blk == 0
    nk = Smax // k_blk
    scale = scale or 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(_kernel, scale=scale, k_blk=k_blk, nk=nk,
                               window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, k_blk, 1, D),
                         lambda b, h, ki, lens: (b, ki, h, 0)),
            pl.BlockSpec((1, k_blk, 1, D),
                         lambda b, h, ki, lens: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, Hq, D)
