"""Jitted public wrapper for ssd_scan (model layout [B,S,H,*])."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_op(q, k, v, log_f, log_i, *, chunk: int = 128,
                interpret: bool = False):
    """q,k: [B,S,H,dk]; v: [B,S,H,dv]; log_f/log_i: [B,S,H] ->
    (y [B,S,H,dv], final_state [B,H,dk,dv]) — drop-in for
    repro.models.linear_core.chunked_linear_attention."""
    tobh = lambda x: jnp.swapaxes(x, 1, 2)
    y, state = ssd_scan(tobh(q), tobh(k), tobh(v),
                        jnp.swapaxes(log_f, 1, 2),
                        jnp.swapaxes(log_i, 1, 2),
                        chunk=chunk, interpret=interpret)
    return jnp.swapaxes(y, 1, 2), state
