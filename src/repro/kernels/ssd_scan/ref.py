"""Pure-jnp oracle for ssd_scan — delegates to the model-internal chunked
linear-attention core (which is itself tested against a stepwise recurrence
in tests/test_linear_core.py), with the [B,H,S,*] kernel layout."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.linear_core import chunked_linear_attention


def ssd_scan_ref(q, k, v, log_f, log_i, *, chunk: int = 128):
    """q,k: [B,H,S,dk]; v: [B,H,S,dv]; gates [B,H,S] ->
    (y [B,H,S,dv], state [B,H,dk,dv])."""
    tohsd = lambda x: jnp.swapaxes(x, 1, 2)      # [B,S,H,*]
    y, state = chunked_linear_attention(
        tohsd(q), tohsd(k), tohsd(v),
        jnp.swapaxes(log_f, 1, 2), jnp.swapaxes(log_i, 1, 2),
        chunk=chunk)
    return jnp.swapaxes(y, 1, 2), state
