"""Pallas TPU chunked linear-attention scan (the mLSTM / mamba-SSD hot path).

The recurrence S_t = f_t·S + i_t·k_t v_tᵀ, y_t = q_t·S_t is computed in
chunkwise-parallel form (models/linear_core.py is the jnp twin): grid
(B, H, n_chunks) with the chunk dim innermost — TPU grids iterate
sequentially, so the [dk, dv] matrix state lives in VMEM scratch across
chunks. Per chunk everything is MXU work: one [W,W] decay-masked score
matmul + two state matmuls. Log-space decay ratios are <= 0 before exp, so
fp32 scratch is stable at any sequence length — this is what makes
long_500k run as a sequence of W-sized tiles with O(dk·dv) carried state."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, f_ref, i_ref, y_ref, s_out_ref, state_ref,
            *, nc: int, W: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [W, dk]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)          # [W, dv]
    log_f = f_ref[0, 0].astype(jnp.float32)      # [W]
    log_i = i_ref[0, 0].astype(jnp.float32)
    cum = jnp.cumsum(log_f)                      # inclusive

    # inter-chunk: contribution of the carried state
    y_state = (q * jnp.exp(cum)[:, None]) @ state_ref[...]
    # intra-chunk: decay-masked scores
    s = q @ k.T                                  # [W, W]
    rows = jax.lax.broadcasted_iota(jnp.int32, (W, W), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (W, W), 1)
    decay = cum[:, None] - cum[None, :] + log_i[None, :]
    decay = jnp.where(rows >= cols, decay, -jnp.inf)
    y = y_state + (s * jnp.exp(decay)) @ v
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update
    tot = cum[-1]
    k_scaled = k * jnp.exp(tot - cum + log_i)[:, None]
    state_ref[...] = state_ref[...] * jnp.exp(tot) + k_scaled.T @ v

    @pl.when(ci == nc - 1)
    def _final():
        s_out_ref[0, 0] = state_ref[...].astype(s_out_ref.dtype)


def ssd_scan(q, k, v, log_f, log_i, *, chunk: int = 128,
             interpret: bool = False):
    """q,k: [B,H,S,dk]; v: [B,H,S,dv]; log_f/log_i: [B,H,S] (log_f <= 0).

    Returns (y [B,H,S,dv], final_state [B,H,dk,dv] fp32)."""
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    W = min(chunk, S)
    assert S % W == 0, (S, W)
    nc = S // W

    kernel = functools.partial(_kernel, nc=nc, W=W)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, W, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, W, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, W, dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, W), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, W), lambda b, h, c: (b, h, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, W, dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, dv), q.dtype),
            jax.ShapeDtypeStruct((B, H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_f, log_i)
    return y, state
