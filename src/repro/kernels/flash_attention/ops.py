"""Jitted public wrapper for the flash attention kernel.

Accepts the model-layout tensors ([B, S, H, D]) used across repro.models and
handles the [B, H, S, D] kernel layout + GQA plumbing. On CPU containers
pass interpret=True (kernel body executes in Python); on TPU the same call
compiles to Mosaic."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_blk",
                                             "k_blk", "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       q_blk: int = 128, k_blk: int = 128,
                       interpret: bool = False):
    """q: [B, Sq, Hq, D]; k/v: [B, Sk, Hkv, D] -> [B, Sq, Hq, D]."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = flash_attention(qt, kt, vt, causal=causal, window=window,
                        q_blk=q_blk, k_blk=k_blk, interpret=interpret)
    return jnp.swapaxes(o, 1, 2)
