"""Pure-jnp oracle for flash_attention (the correctness ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale=None):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Sk, D] -> [B, Hq, Sq, D]."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale or 1.0 / math.sqrt(D)
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    rows = jnp.arange(Sq)[:, None]
    cols = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
