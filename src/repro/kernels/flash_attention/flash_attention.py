"""Pallas TPU flash attention (prefill hot path).

Grid (B, Hq, nQ, nK) — TPU grids iterate sequentially with the last dim
innermost, so the online-softmax state for one (b, h, qi) lives in VMEM
scratch across the nK inner iterations. BlockSpecs tile Q/K/V into VMEM
with MXU-aligned (multiple-of-128 recommended) block shapes; GQA is handled
in the K/V index maps (q head h reads kv head h // group).

Causal block skipping: blocks strictly above the diagonal contribute
nothing; `pl.when` guards the whole update so the MXU never sees them."""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, q_blk: int, k_blk: int, nk: int,
            window: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = qi * q_blk
    k_lo = ki * k_blk
    # is any (row, col) pair in this block unmasked?
    live = jnp.bool_(True)
    if causal:
        live = live & (k_lo <= q_lo + q_blk - 1)
    if window > 0:
        live = live & (k_lo + k_blk - 1 > q_lo - window)

    @pl.when(live)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # [q_blk, d]
        k = k_ref[0, 0].astype(jnp.float32)              # [k_blk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 1)
        mask = jnp.ones((q_blk, k_blk), jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_blk: int = 128, k_blk: int = 128,
                    scale=None, interpret: bool = False):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Sk, D] -> [B, Hq, Sq, D]."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    q_blk = min(q_blk, Sq)
    k_blk = min(k_blk, Sk)
    assert Sq % q_blk == 0 and Sk % k_blk == 0
    nq, nk = Sq // q_blk, Sk // k_blk
    scale = scale or 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, q_blk=q_blk, k_blk=k_blk,
        nk=nk, window=window)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, k_blk, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, k_blk, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, D), jnp.float32),   # acc
            pltpu.VMEM((q_blk,), jnp.float32),     # running max m
            pltpu.VMEM((q_blk,), jnp.float32),     # running sum l
        ],
        interpret=interpret,
    )(q, k, v)
