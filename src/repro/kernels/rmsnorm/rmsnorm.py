"""Pallas TPU fused RMSNorm.

Small but on the decode critical path (2 per layer). Fusing the
mean-square reduction, rsqrt, and scale into one VMEM pass avoids three
HBM round-trips for the activation tensor. Rows are tiled [row_blk, d];
statistics are computed in fp32."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, w, *, eps: float = 1e-5, row_blk: int = 256,
            interpret: bool = False):
    """x: [N, d]; w: [d] -> [N, d]."""
    N, d = x.shape
    row_blk = min(row_blk, N)
    assert N % row_blk == 0, (N, row_blk)
    kernel = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // row_blk,),
        in_specs=[
            pl.BlockSpec((row_blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((row_blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, w)
