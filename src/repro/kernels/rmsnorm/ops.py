"""Jitted public wrapper for the fused RMSNorm kernel (any leading dims)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.rmsnorm import rmsnorm


@functools.partial(jax.jit, static_argnames=("eps", "row_blk", "interpret"))
def rmsnorm_op(x, w, *, eps: float = 1e-5, row_blk: int = 256,
               interpret: bool = False):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    n = x2.shape[0]
    blk = row_blk
    while n % blk:
        blk //= 2
    y = rmsnorm(x2, w, eps=eps, row_blk=max(1, blk), interpret=interpret)
    return y.reshape(shape)
