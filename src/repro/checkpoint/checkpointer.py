"""Shard-wise checkpointing with elastic re-shard on restore.

Format: one .npz per step holding flattened arrays + a JSON manifest with
the tree structure. Arrays are fully materialized per host here (single-host
container); on a real multi-host pod each host would write its addressable
shards — the manifest layout already records per-leaf shape/dtype so that
extension is mechanical. Restore accepts a different mesh/sharding than the
save used (elastic scaling): arrays are loaded then device_put against the
new shardings.

Atomicity: writes go to a temp name then os.replace (crash-safe); restore
picks the latest *complete* step."""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


_NPZ_SAFE = {"float64", "float32", "float16", "int64", "int32", "int16",
             "int8", "uint64", "uint32", "uint16", "uint8", "bool"}
_BITS = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, trees: Dict[str, Any]) -> None:
        """trees: e.g. {"params": ..., "opt": ..., "extra": ...}."""
        tmp = self.dir / f".tmp_step_{step:08d}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest: Dict[str, Any] = {"step": step, "trees": {}}
        for name, tree in trees.items():
            paths, leaves, _ = _flatten_with_paths(tree)
            arrays = {}
            meta: List[Dict[str, Any]] = []
            for i, (p, leaf) in enumerate(zip(paths, leaves)):
                arr = np.asarray(jax.device_get(leaf))
                key = f"a{i}"
                logical = str(arr.dtype)
                if arr.dtype.kind == "V" or logical not in _NPZ_SAFE:
                    # exotic dtypes (bfloat16, fp8) stored as raw bits
                    arr = np.atleast_1d(arr).view(_BITS[arr.dtype.itemsize])
                arrays[key] = arr
                meta.append({"path": p, "key": key, "shape": list(arr.shape),
                             "dtype": logical})
            np.savez(tmp / f"{name}.npz", **arrays)
            manifest["trees"][name] = meta
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def restore(self, step: int, name: str, target,
                shardings: Any = None):
        """Restore tree ``name`` at ``step``.

        ``target``: a pytree of arrays or ShapeDtypeStructs giving the tree
        structure. ``shardings``: matching tree of NamedShardings (may be
        built against a *different* mesh than the save — elastic)."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / f"{name}.npz")
        meta = manifest["trees"][name]
        by_path = {}
        for m in meta:
            arr = data[m["key"]]
            if m["dtype"] not in _NPZ_SAFE:       # restore exotic bit views
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, m["dtype"])))
                arr = arr.reshape(m["shape"])
            by_path[m["path"]] = arr
        paths, leaves, treedef = _flatten_with_paths(target)
        sh_leaves = [None] * len(leaves)
        if shardings is not None:
            sh_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        out = []
        for p, leaf, sh in zip(paths, leaves, sh_leaves):
            if p not in by_path:
                raise KeyError(f"checkpoint missing leaf {p!r}")
            arr = by_path[p]
            want_shape = tuple(leaf.shape)
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"shape mismatch for {p}: ckpt {arr.shape} vs {want_shape}")
            dtype = leaf.dtype
            val = jnp.asarray(arr, dtype=dtype)
            out.append(jax.device_put(val, sh) if sh is not None else val)
        return jax.tree.unflatten(treedef, out)

    def restore_named_tuple(self, step, name, target, shardings=None):
        return self.restore(step, name, target, shardings)
