"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these (weak-type-correct, shardable, no device allocation).

For modality-stub archs (vlm/audio) the frontend output arrives as
precomputed embeddings per DESIGN.md §4."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec

I32 = jnp.int32
F32 = jnp.float32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        S_dec = S // cfg.decoder_ratio
        return {
            "frames": _sds((B, S, cfg.d_model), F32),
            "tokens": _sds((B, S_dec), I32),
            "labels": _sds((B, S_dec), I32),
        }
    if cfg.frontend == "vision":
        P = cfg.num_prefix_embeddings
        S_text = S - P
        return {
            "prefix_embeddings": _sds((B, P, cfg.d_model), F32),
            "tokens": _sds((B, S_text), I32),
            "labels": _sds((B, S_text), I32),
        }
    return {"tokens": _sds((B, S), I32), "labels": _sds((B, S), I32)}


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b = train_batch_specs(cfg, shape)
    b.pop("labels", None)
    return b


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Any, Any]:
    """(tokens [B,1], lengths [B]) for serve_decode."""
    B = shape.global_batch
    return _sds((B, 1), I32), _sds((B,), I32)


def batch_axes_tree(batch_specs: Dict[str, Any]):
    """Logical axes for each batch input (batch dim sharded, rest replicated)."""
    out = {}
    for k, v in batch_specs.items():
        out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


def make_concrete(batch_specs: Dict[str, Any], rng=None, vocab: int = 1000):
    """Materialize small concrete batches for smoke tests."""
    import numpy as np
    r = np.random.default_rng(0)
    out = {}
    for k, v in batch_specs.items():
        if v.dtype == I32:
            out[k] = jnp.asarray(r.integers(0, vocab, v.shape), I32)
        else:
            out[k] = jnp.asarray(r.normal(size=v.shape) * 0.02, F32)
    return out
