"""Parse compiled (post-SPMD) HLO text for roofline inputs.

XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of trip
count (verified empirically — see EXPERIMENTS.md §Methodology), so anything
inside ``lax.scan``/``fori_loop`` is undercounted. This parser rebuilds
per-device totals:

* collective bytes by op type (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute), using ring-algorithm wire-byte models,
* dot FLOPs (matmuls, including those inside fusions),
* each multiplied by the product of enclosing while-loop trip counts
  (constant bounds parsed from loop conditions; data-dependent bounds fall
  back to caller-supplied estimates).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALL_ATTR_RE = re.compile(r"(?:condition|body|calls|to_apply)=%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of possibly-tuple HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # op name -> type str


def _parse_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    current: Optional[Computation] = None
    header = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if current is None:
            m = header.match(line.strip())
            if m:
                current = Computation(m.group(2))
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        current.lines.append(line)
        dm = _DEF_RE.match(line)
        if dm:
            rest = dm.group(2)
            tm = re.match(r"((?:\([^)]*\))|(?:[\w\[\],{}]+))\s", rest)
            if tm:
                current.shapes[dm.group(1)] = tm.group(1)
    return comps, entry


def _trip_count(cond: Computation) -> Optional[int]:
    """Constant loop bound from a while condition (None if data-dependent)."""
    consts = []
    has_compare = False
    for line in cond.lines:
        if "compare(" in line or "wrapped_compare" in line:
            has_compare = True
        for m in re.finditer(r"constant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    if has_compare and consts:
        return max(consts)
    return None


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _operand_names(line: str) -> List[str]:
    m = re.search(r"\w[\w\-]*\(([^)]*)\)", line)
    if not m:
        return []
    return re.findall(r"%([\w.\-]+)", m.group(1))


@dataclass
class HloStats:
    collective_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    dot_flops: float = 0.0
    unknown_trip_whiles: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    def to_dict(self):
        return {
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "total_collective_bytes": self.total_collective_bytes,
            "dot_flops": self.dot_flops,
            "unknown_trip_whiles": self.unknown_trip_whiles,
        }


def analyze_hlo(hlo: str, *, total_devices: int,
                default_trip: int = 1) -> HloStats:
    """Per-device collective bytes + dot flops with loop-trip multipliers.

    default_trip: multiplier assumed for while loops whose bound is
    data-dependent (e.g. causal fori_loop attention) — callers pass the
    analytically-known average trip count."""
    comps, entry = _parse_computations(hlo)
    stats = HloStats()
    if entry is None:
        return stats

    def dims_product(dims_str: str) -> int:
        n = 1
        if dims_str:
            for d in dims_str.split(","):
                n *= int(d)
        return n

    def visit(comp_name: str, mult: float, seen: Tuple[str, ...]):
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen:
            return
        seen = seen + (comp_name,)
        for line in comp.lines:
            # --- while loops ---
            if re.search(r"\bwhile\(", line):
                attrs = dict(re.findall(r"(condition|body)=%([\w.\-]+)", line))
                trip = None
                if "condition" in attrs and attrs["condition"] in comps:
                    trip = _trip_count(comps[attrs["condition"]])
                if trip is None:
                    trip = default_trip
                    stats.unknown_trip_whiles += 1
                if "body" in attrs:
                    visit(attrs["body"], mult * trip, seen)
                continue
            # --- calls into fusions / custom computations ---
            for sub in _CALL_ATTR_RE.findall(line):
                if sub in comps and "while(" not in line:
                    visit(sub, mult, seen)
            # --- collectives ---
            low = line.lstrip()
            for coll in COLLECTIVES:
                if re.search(rf"\b{coll}\(", low) and "-start(" not in low \
                        and "-done(" not in low:
                    dm = _DEF_RE.match(line)
                    if not dm:
                        continue
                    result_bytes = _shape_bytes(
                        comp.shapes.get(dm.group(1), ""))
                    g = _group_size(line, total_devices)
                    frac = (g - 1) / g if g > 1 else 0.0
                    if coll == "all-gather":
                        wire = result_bytes * frac
                    elif coll == "all-reduce":
                        wire = 2.0 * result_bytes * frac
                    elif coll in ("reduce-scatter", "all-to-all"):
                        ops = _operand_names(line)
                        op_bytes = sum(_shape_bytes(comp.shapes.get(o, ""))
                                       for o in ops) or result_bytes
                        wire = op_bytes * frac
                    else:  # collective-permute
                        wire = result_bytes
                    stats.collective_bytes[coll] += wire * mult
                    stats.collective_counts[coll] += 1
                    break
            # --- dots ---
            if re.search(r"\bdot\(", low):
                dm = _DEF_RE.match(line)
                if not dm:
                    continue
                rm = _SHAPE_RE.search(comp.shapes.get(dm.group(1), ""))
                if not rm:
                    continue
                out_elems = dims_product(rm.group(2))
                ops = _operand_names(line)
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                contracted = 1
                if ops and cm and ops[0] in comp.shapes:
                    lhs = _SHAPE_RE.search(comp.shapes[ops[0]])
                    if lhs:
                        ldims = ([int(x) for x in lhs.group(2).split(",")]
                                 if lhs.group(2) else [])
                        for ci in (cm.group(1).split(",") if cm.group(1) else []):
                            ci = int(ci)
                            if ci < len(ldims):
                                contracted *= ldims[ci]
                stats.dot_flops += 2.0 * out_elems * contracted * mult

    visit(entry, 1.0, ())
    return stats
