"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Stands up the continuous-batching LMServer (AIMD admission, slot decode)
on the elastic local mesh and drives it with a synthetic request stream —
the CPU-scale twin of the production 16x16 deployment the dry-run lowers."""

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config, reduced_config
from repro.distributed.sharding import serve_rules
from repro.launch.mesh import make_elastic_mesh
from repro.models.api import build_model
from repro.serving.engine import LMServer


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg, num_layers=4, d_model=128)
    mesh = make_elastic_mesh()
    rules = serve_rules(multi_pod=False)
    model = build_model(cfg, mesh, rules)
    params = model.init(jax.random.PRNGKey(0))
    server = LMServer(model, mesh, rules, slots=args.slots,
                      max_len=args.max_len, temperature=args.temperature)
    rng = np.random.default_rng(0)
    print(f"serving {cfg.name} on mesh {dict(mesh.shape)}; "
          f"{args.requests} requests x {args.max_new} tokens")
    t0 = time.perf_counter()
    rids = [server.submit(rng.integers(0, cfg.vocab_size, args.prompt_len),
                          max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    server.run(params)
    dt = time.perf_counter() - t0
    toks = sum(len(server.completed[r].tokens) for r in rids)
    print(f"completed {len(server.completed)}/{args.requests} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.0f} tok/s); "
          f"AIMD admission batch = {server.admission.max_batch_size}")


if __name__ == "__main__":
    main()
