"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three per-chip time terms:

    T_compute = HLO_dot_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
    T_memory  = (argument + output bytes) / HBM_bw    (819 GB/s)
    T_coll    = collective wire bytes / link_bw       (50 GB/s per link)

Sources & corrections (verified in tests/test_hlo_stats.py):
  * FLOPs come from the HLO dot parser with while-loop trip-count
    multiplication — XLA's cost_analysis() counts scan bodies once and is
    reported only as a cross-reference.
  * Memory traffic uses memory_analysis() argument+output bytes — the
    perfect-fusion lower bound on HBM traffic (weights/caches/optimizer
    state read once, outputs written once); temp bytes are reported as
    footprint, not traffic.
  * Collective bytes are ring-model wire bytes per device, trip-multiplied.

MODEL_FLOPS (the "useful" numerator) = 6·N_active·tokens (train) or
2·N_active·tokens (serve), logical (unpadded) parameter counts.

roofline_fraction = ideal_time / bound_time, where
    ideal_time = max(MODEL_FLOPS_per_chip / peak, T_memory)
    bound_time = max(T_compute, T_memory, T_coll)
(T_memory appears in both because argument+output traffic is already the
idealized floor — a fraction of 1.0 means no wasted compute and no
collective bottleneck beyond the intrinsic memory floor.)
"""

from __future__ import annotations

import argparse
import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import ARCHITECTURES

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    t_compute: float
    t_memory: float
    t_coll: float
    model_flops_chip: float
    hlo_flops_chip: float
    ideal_bytes_chip: float
    temp_gb: float
    args_gb: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return (self.model_flops_chip / self.hlo_flops_chip
                if self.hlo_flops_chip else 0.0)

    @property
    def ideal_time(self) -> float:
        return max(self.model_flops_chip / PEAK_FLOPS,
                   self.ideal_bytes_chip / HBM_BW)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_coll)

    @property
    def fraction(self) -> float:
        return self.ideal_time / self.bound_time if self.bound_time else 0.0

    def advice(self) -> str:
        d = self.dominant
        if d == "collective":
            return ("reduce cross-device traffic: fewer FSDP regathers / "
                    "all_to_all dispatch instead of token gather / "
                    "compressed reductions")
        if d == "compute" and self.useful_ratio < 0.5:
            return ("cut wasted FLOPs: causal block skipping, less remat "
                    "recompute, tighter MoE capacity, unpadded heads")
        if d == "compute":
            return "compute-bound near useful FLOPs: scale batch or chips"
        return ("memory-bound: shrink resident state (split-scan window "
                "caches, quantized KV, Adafactor) or raise arithmetic "
                "intensity (bigger batch)")


def ideal_bytes_per_chip(arch: str, shape_name: str, chips: int) -> float:
    """Analytic HBM-traffic floor per chip using *logical* (unpadded) state:
    what a perfect implementation would move. Decode: active weights + the
    logical KV/recurrent state (window-bounded where the arch allows).
    Prefill: weights + logical cache written. Train: full optimizer-state
    read+write (28 B/param: bf16 p r/w + fp32 master/m/v r/w)."""
    cfg = ARCHITECTURES[arch]
    shape = SHAPES_BY_NAME[shape_name]
    n = cfg.param_count()
    n_active = cfg.active_param_count()
    S, B = shape.seq_len, shape.global_batch
    hd = cfg.resolved_head_dim

    def cache_bytes(seq: int) -> float:
        per_layer = []
        for li in range(cfg.num_layers):
            if cfg.family == "ssm":
                per_layer.append(2 * cfg.num_heads * (2 * cfg.d_model // cfg.num_heads) ** 2 * 4)
                continue
            w = cfg.window if (cfg.window and li not in cfg.global_layers) else 0
            eff = min(seq, w) if w else seq
            per_layer.append(2 * eff * cfg.num_kv_heads * hd * 2)   # bf16 K+V
        if cfg.is_encoder_decoder:
            cross = 2 * seq * cfg.num_kv_heads * hd * 2
            self_ = 2 * (seq // cfg.decoder_ratio) * cfg.num_kv_heads * hd * 2
            return B * cfg.num_layers * (cross + self_)
        return B * sum(per_layer)

    if shape.kind == "train":
        return 28.0 * n / chips
    if shape.kind == "prefill":
        return (2.0 * n + cache_bytes(S)) / chips
    return (2.0 * n_active + cache_bytes(S)) / chips


def model_flops_per_chip(arch: str, shape_name: str, chips: int) -> float:
    cfg = ARCHITECTURES[arch]
    shape = SHAPES_BY_NAME[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.is_encoder_decoder:
            tokens = shape.global_batch * (shape.seq_len
                                           + shape.seq_len // cfg.decoder_ratio)
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    tokens = shape.global_batch           # one token per sequence
    return 2.0 * n_active * tokens / chips


def load_cell(path: Path) -> Optional[CellRoofline]:
    rec = json.loads(path.read_text())
    if not rec.get("ok"):
        return None
    chips = rec["devices"]
    ma = rec["memory_analysis"]
    traffic = ma["argument_bytes"] + ma["output_bytes"]
    hlo_flops = rec["hlo"]["dot_flops"]
    coll = rec["hlo"]["total_collective_bytes"]
    return CellRoofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        t_compute=hlo_flops / PEAK_FLOPS,
        t_memory=traffic / HBM_BW,
        t_coll=coll / LINK_BW,
        model_flops_chip=model_flops_per_chip(rec["arch"], rec["shape"],
                                              chips),
        hlo_flops_chip=hlo_flops,
        ideal_bytes_chip=ideal_bytes_per_chip(rec["arch"], rec["shape"],
                                              chips),
        temp_gb=ma["temp_bytes"] / 1e9,
        args_gb=ma["argument_bytes"] / 1e9,
    )


def load_all(dryrun_dir: str, mesh: str = "single") -> List[CellRoofline]:
    tag = "single" if mesh == "single" else "multi"
    cells = []
    for p in sorted(Path(dryrun_dir).glob(f"*__{tag}.json")):
        c = load_cell(p)
        if c:
            cells.append(c)
    return cells


def fmt_ms(t: float) -> str:
    if t >= 1.0:
        return f"{t:.2f}s"
    return f"{t*1e3:.2f}ms"


def table(cells: List[CellRoofline]) -> str:
    hdr = ("| arch | shape | T_comp | T_mem | T_coll | dominant | "
           "useful/HLO | frac | state GB/chip | note |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for c in cells:
        lines.append(
            f"| {c.arch} | {c.shape} | {fmt_ms(c.t_compute)} | "
            f"{fmt_ms(c.t_memory)} | {fmt_ms(c.t_coll)} | **{c.dominant}** | "
            f"{c.useful_ratio:.2f} | {c.fraction:.2f} | {c.args_gb:.1f} | "
            f"{c.advice()[:48]} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun/baseline")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    cells = load_all(args.dryrun, args.mesh)
    print(table(cells))
    worst = sorted(cells, key=lambda c: c.fraction)[:5]
    print("\nworst roofline fractions (hillclimb candidates):")
    for c in worst:
        print(f"  {c.arch} x {c.shape}: frac={c.fraction:.3f} "
              f"dominant={c.dominant} — {c.advice()}")
    coll_bound = sorted(cells, key=lambda c: -c.t_coll / max(c.bound_time, 1e-12))[:5]
    print("\nmost collective-bound:")
    for c in coll_bound:
        print(f"  {c.arch} x {c.shape}: T_coll={fmt_ms(c.t_coll)} "
              f"({c.t_coll/max(c.bound_time,1e-12)*100:.0f}% of bound)")
    if args.json:
        out = [dict(arch=c.arch, shape=c.shape, mesh=c.mesh,
                    t_compute=c.t_compute, t_memory=c.t_memory,
                    t_coll=c.t_coll, dominant=c.dominant,
                    useful_ratio=c.useful_ratio, fraction=c.fraction)
               for c in cells]
        Path(args.json).write_text(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
