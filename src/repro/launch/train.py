"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Uses the elastic local mesh (all visible devices) and the same step-builder
the dry-run lowers for the production 16x16 mesh — only the mesh differs.
Checkpoint/restart: re-launching with the same --ckpt resumes."""

import argparse
import dataclasses

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, reduced_config
from repro.data.pipeline import data_iter
from repro.distributed.sharding import train_rules
from repro.launch.mesh import make_elastic_mesh
from repro.models.api import build_model
from repro.training.train_loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--reduced", action="store_true",
                    help="width/depth-reduced config (CPU-friendly)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg, num_layers=6, d_model=256, vocab_size=4096)
        cfg = dataclasses.replace(cfg, d_ff=0 if cfg.d_ff == 0 else 1024)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    mesh = make_elastic_mesh()
    rules = train_rules(multi_pod=False)
    model = build_model(cfg, mesh, rules)
    tc = TrainConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                     total_steps=args.steps,
                     num_microbatches=args.microbatches,
                     optimizer=args.optimizer)
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) on "
          f"mesh {dict(mesh.shape)} for {args.steps} steps")
    with mesh:
        out = train(model, mesh, rules, tc,
                    data_iter(cfg, shape, seed=args.seed),
                    num_steps=args.steps, checkpoint_dir=args.ckpt,
                    log_every=10,
                    hooks={"on_log": lambda m: print(
                        f"  step {m['step']:5d}  loss {m['loss']:.4f}  "
                        f"lr {m['lr']:.2e}")})
    h = out["history"]
    print(f"done: loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
