"""Mesh construction. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.

``compat_make_mesh`` papers over the ``jax.sharding.AxisType`` API, which
only exists on newer JAX (>= 0.5): on older installs (e.g. 0.4.37) meshes
are built without explicit axis types, which is the same Auto behaviour.
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the installed JAX has them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests/benches (defaults to the single real device)."""
    return compat_make_mesh((data, model), ("data", "model"))


def make_elastic_mesh(model_parallelism: int = 16):
    """Build the largest (data, model) mesh the visible devices support —
    elastic scaling: the same launcher works at any device count."""
    n = len(jax.devices())
    model = min(model_parallelism, n)
    while n % model:
        model -= 1
    return compat_make_mesh((n // model, model), ("data", "model"))
