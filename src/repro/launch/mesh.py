"""Mesh construction. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests/benches (defaults to the single real device)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_elastic_mesh(model_parallelism: int = 16):
    """Build the largest (data, model) mesh the visible devices support —
    elastic scaling: the same launcher works at any device count."""
    n = len(jax.devices())
    model = min(model_parallelism, n)
    while n % model:
        model -= 1
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
