"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the placeholder-device flag before ANY other import (jax locks the
device count on first init)."""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402

from repro.configs.base import applicable_shapes  # noqa: E402
from repro.configs.registry import ARCHITECTURES, get_config, get_shape  # noqa: E402
from repro.launch import hlo_stats  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_step  # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             step_opts=None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "devices": int(len(jax.devices())),
        "step_opts": dict(step_opts or {}),
    }
    t0 = time.time()
    try:
        with mesh:
            bundle = build_step(cfg, shape, mesh, **(step_opts or {}))
            lowered = bundle.fn.lower(*bundle.arg_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            txt = compiled.as_text()
            if shape.kind == "prefill":
                k_block = (step_opts or {}).get("k_block", 1024)
                default_trip = max(1, (shape.seq_len // k_block + 1) // 2)
            else:
                default_trip = 1
            hs = hlo_stats.analyze_hlo(txt, total_devices=len(jax.devices()),
                                       default_trip=default_trip)
        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "meta": bundle.meta,
            "memory_analysis": {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "code_bytes": int(ma.generated_code_size_in_bytes),
            },
            "cost_analysis": {
                "flops_body_once": float(ca.get("flops", 0.0)),
                "bytes_accessed_body_once": float(ca.get("bytes accessed", 0.0)),
            },
            "hlo": hs.to_dict(),
            "hlo_text_bytes": len(txt),
            "default_trip": default_trip,
        })
        if verbose:
            mb = 1 / (1 << 20)
            print(f"OK  {arch} x {shape_name} x {rec['mesh']}: "
                  f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
                  f"args {ma.argument_size_in_bytes * mb:.0f}MB "
                  f"temp {ma.temp_size_in_bytes * mb:.0f}MB | "
                  f"coll {hs.total_collective_bytes * mb:.1f}MB | "
                  f"dotF {hs.dot_flops:.3e}")
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc(limit=20),
                    "elapsed_s": round(time.time() - t0, 2)})
        if verbose:
            print(f"FAIL {arch} x {shape_name} x {rec['mesh']}: {rec['error']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have results")
    ap.add_argument("--tag", default="baseline",
                    help="variant tag for §Perf iterations")
    ap.add_argument("--opts", default="{}",
                    help="JSON step opts (e.g. remat, q_block, optimizer)")
    args = ap.parse_args()

    outdir = Path(args.out) / args.tag
    outdir.mkdir(parents=True, exist_ok=True)
    step_opts = json.loads(args.opts)

    archs = sorted(ARCHITECTURES) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([s.name for s in applicable_shapes(cfg)]
                  if args.shape == "all" else args.shape.split(","))
        for shape_name in shapes:
            for multi in meshes:
                mesh_tag = "multi" if multi else "single"
                path = outdir / f"{arch}__{shape_name}__{mesh_tag}.json"
                if path.exists() and not args.force:
                    prev = json.loads(path.read_text())
                    if prev.get("ok"):
                        n_skip += 1
                        continue
                rec = run_cell(arch, shape_name, multi, step_opts=step_opts)
                path.write_text(json.dumps(rec, indent=1))
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"\ndone: {n_ok} ok, {n_fail} fail, {n_skip} cached")


if __name__ == "__main__":
    main()
