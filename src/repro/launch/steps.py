"""Step-function factory shared by the trainer, serving engine and dry-run.

For each (arch, shape) cell this builds the *exact* jitted function the
production system would execute, with explicit in/out shardings — the dry-run
lowers these against ShapeDtypeStructs; the trainer/engine call them with
real arrays. One code path, no divergence."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec, suggest_microbatches
from repro.distributed.sharding import (
    ShardingContext, params_shardings, serve_rules, sharding_context,
    train_rules,
)
from repro.launch.inputs import (
    batch_axes_tree, decode_input_specs, prefill_batch_specs,
    train_batch_specs,
)
from repro.models.api import Model, build_model


def rules_for(cfg: ModelConfig, kind: str, multi_pod: bool,
              moe_mode: Optional[str] = None) -> Dict[str, Any]:
    """moe_mode (serve, big MoE only):
      '2d'     — experts x model, d_ff x data; tokens gathered over data
                 (baseline; right for decode where tokens are tiny)
      'gather' — experts x model, d_model x data (FSDP-style storage);
                 expert *weights* gathered per layer (the §Perf fix for
                 prefill, where token bytes >> expert-slice bytes)."""
    if kind == "train":
        return train_rules(multi_pod)
    # MoE whose model-sharded experts exceed ~half of HBM needs a second
    # sharding dimension at serve (DESIGN.md §6)
    expert_bytes_tp = (cfg.num_layers * cfg.num_experts * 3 * cfg.d_model
                       * cfg.d_ff * 2 / 16)
    big_moe = expert_bytes_tp > 8e9
    if not big_moe:
        return serve_rules(multi_pod, shard_experts_2d=False)
    if (moe_mode or "2d") == "2d":
        return serve_rules(multi_pod, shard_experts_2d=True)
    rules = serve_rules(multi_pod, shard_experts_2d=False)
    rules["fsdp"] = "data"          # gather-weights mode
    return rules


def fit_batch_sharding(rules: Dict[str, Any], mesh, global_batch: int
                       ) -> Dict[str, Any]:
    """Drop batch-sharding axes that don't divide the global batch (e.g.
    long_500k's global_batch=1 cannot shard over 16 data shards)."""
    axes = rules.get("batch")
    axes = tuple(a for a in ((axes,) if isinstance(axes, str) else (axes or ()))
                 if a in mesh.shape)

    def fits(t):
        n = 1
        for a in t:
            n *= mesh.shape[a]
        return n and global_batch % n == 0

    while axes and not fits(axes):
        axes = axes[:-1]
    rules = dict(rules)
    rules["batch"] = axes or None
    rules["users"] = rules["batch"]
    return rules


def _axes_sh(ctx: ShardingContext, axes_tree_):
    return jax.tree.map(lambda ax: ctx.sharding(ax), axes_tree_,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(a is None or isinstance(a, str) for a in x))


@dataclasses.dataclass
class StepBundle:
    """A jitted step + everything needed to lower or call it."""
    fn: Any                       # jitted callable
    arg_specs: Tuple[Any, ...]    # ShapeDtypeStructs for .lower(*arg_specs)
    model: Model
    rules: Dict[str, Any]
    meta: Dict[str, Any]


def build_train_step(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                     optimizer: str = "adamw", remat: str = "full",
                     pod_compress: bool = True,
                     sequence_parallel: bool = False,
                     dp_major: bool = False,
                     num_microbatches: Optional[int] = None) -> StepBundle:
    from repro.training.train_loop import TrainConfig, jit_train_step

    multi_pod = "pod" in mesh.shape
    rules = fit_batch_sharding(rules_for(cfg, "train", multi_pod), mesh,
                               shape.global_batch)
    if sequence_parallel:
        # §Perf: residual stream seq-sharded over model (Megatron-SP style,
        # via XLA's partitioner) — remat'd block inputs shrink 16x, so one
        # big microbatch replaces many (16x fewer FSDP weight regathers)
        rules["seq"] = "model"
    if dp_major:
        # §Perf: batch sharded over data x model (1 sample/chip at gb=256)
        # — no TP activation all-reduces at all; dense weights 2-D sharded
        # and gathered per layer; MoE gathers tokens over the model column
        # (moe._moe_body_ep gather_model path). The spec-dedupe in
        # sharding.py keeps expert tensors at (model, data) automatically.
        # Only worthwhile when TP-activation bytes dominate weight bytes —
        # it REGRESSES small replicated models (xlstm: 5x worse; §Perf).
        nshards = mesh.shape.get("data", 1) * mesh.shape.get("model", 1)
        fsdp_axes = (("data", "model") if cfg.d_model % nshards == 0
                     else ("data",))   # divisibility fallback (e.g. d=960)
        rules.update(batch=("pod", "data", "model") if multi_pod
                     else ("data", "model"),
                     fsdp=fsdp_axes,
                     heads=None, kv_heads=None, ffn=None, vocab=None)
        rules = fit_batch_sharding(rules, mesh, shape.global_batch)
    # the model only ever sees per-pod batches (the cross-pod dim is handled
    # by the gradient shard_map), so its internal rules are pod-free
    from repro.distributed.sharding import strip_pod
    rules_model = strip_pod(rules) if multi_pod else rules
    model = build_model(cfg, mesh, rules_model, remat=remat)
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    nmb = num_microbatches or suggest_microbatches(cfg, shape, dp)
    tc = TrainConfig(num_microbatches=nmb, optimizer=optimizer,
                     pod_compress=pod_compress)
    batch_specs = train_batch_specs(cfg, shape)
    step, opt_init, sh, batch_sh = jit_train_step(model, mesh, rules_model, tc,
                                                  batch_specs,
                                                  batch_rules=rules)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(opt_init, params_shape)
    return StepBundle(
        fn=step,
        arg_specs=(params_shape, opt_shape, batch_specs),
        model=model, rules=rules,
        meta={"kind": "train", "num_microbatches": nmb, "optimizer": optimizer,
              "remat": remat},
    )


def build_prefill_step(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                       remat: str = "none", max_len: Optional[int] = None,
                       q_block: int = 512, k_block: int = 1024,
                       moe_mode: Optional[str] = "gather",
                       context_parallel: bool = False) -> StepBundle:
    multi_pod = "pod" in mesh.shape
    rules = fit_batch_sharding(
        rules_for(cfg, "serve", multi_pod, moe_mode=moe_mode), mesh,
        shape.global_batch)
    if context_parallel:
        rules["seq"] = "model"      # §Perf: context-parallel dense prefill
    model = build_model(cfg, mesh, rules, remat=remat,
                        q_block=q_block, k_block=k_block)
    ctx = ShardingContext(mesh, rules)
    batch_specs = prefill_batch_specs(cfg, shape)
    batch_sh = _axes_sh(ctx, batch_axes_tree(batch_specs))
    param_sh = _axes_sh(ctx, model.param_axes)
    B = shape.global_batch
    S = _dec_len(cfg, shape)
    Smax = max_len or S
    cache_ax = model.cache_axes(B, Smax)
    cache_sh = _axes_sh(ctx, cache_ax)
    logits_sh = ctx.sharding(("batch", "vocab"))

    def serve_prefill(params, batch):
        with sharding_context(mesh, rules):
            return model.prefill(params, batch, max_len=Smax)

    fn = jax.jit(serve_prefill, in_shardings=(param_sh, batch_sh),
                 out_shardings=(logits_sh, cache_sh))
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return StepBundle(fn=fn, arg_specs=(params_shape, batch_specs),
                      model=model, rules=rules,
                      meta={"kind": "prefill", "max_len": Smax})


def build_decode_step(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                      remat: str = "none",
                      moe_mode: Optional[str] = None) -> StepBundle:
    multi_pod = "pod" in mesh.shape
    rules = fit_batch_sharding(
        rules_for(cfg, "serve", multi_pod, moe_mode=moe_mode), mesh,
        shape.global_batch)
    model = build_model(cfg, mesh, rules, remat=remat)
    ctx = ShardingContext(mesh, rules)
    B = shape.global_batch
    S = shape.seq_len
    if cfg.is_encoder_decoder:
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(B, S // cfg.decoder_ratio, enc_len=S))
        cache_ax = model.cache_axes(B, S // cfg.decoder_ratio, enc_len=S)
    else:
        cache_shape = jax.eval_shape(lambda: model.init_cache(B, S))
        cache_ax = model.cache_axes(B, S)
    cache_sh = _axes_sh(ctx, cache_ax)
    param_sh = _axes_sh(ctx, model.param_axes)
    tok_specs, len_specs = decode_input_specs(cfg, shape)
    tok_sh = ctx.sharding(("batch", None))
    len_sh = ctx.sharding(("batch",))
    logits_sh = ctx.sharding(("batch", "vocab"))

    def serve_decode(params, cache, tokens, lengths):
        with sharding_context(mesh, rules):
            return model.decode_step(params, cache, tokens, lengths)

    fn = jax.jit(serve_decode,
                 in_shardings=(param_sh, cache_sh, tok_sh, len_sh),
                 out_shardings=(logits_sh, cache_sh),
                 donate_argnums=(1,))
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return StepBundle(fn=fn,
                      arg_specs=(params_shape, cache_shape, tok_specs, len_specs),
                      model=model, rules=rules,
                      meta={"kind": "decode", "cache_len": S})


def _dec_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    if cfg.is_encoder_decoder:
        return shape.seq_len // cfg.decoder_ratio
    return shape.seq_len


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh, **opts) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **opts)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, **opts)
    return build_decode_step(cfg, shape, mesh, **opts)
