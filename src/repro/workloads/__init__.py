"""Workload scenarios: open-loop arrival-trace generators plus a
``ScenarioRunner`` that replays a trace through either serving stack
(discrete-event ``Clipper`` frontend or continuous-batching ``LMServer``)
and emits the shared ``repro.metrics/v1`` report.

Everything is deterministic from a seed — the same scenario run twice
produces byte-identical reports — which is what makes tail latency, SLO
attainment, and batch-size adaptation exact test oracles (paper Figs 4/6/9
methodology; DESIGN.md §9).
"""

from repro.workloads.scenario import (SCENARIOS, Scenario, ScenarioRunner,
                                      frontend_models, run_scenario,
                                      trace_meta)
from repro.workloads.traces import (bursty_trace, diurnal_trace,
                                    flash_crowd_trace, poisson_trace,
                                    query_trace)

__all__ = [
    "SCENARIOS", "Scenario", "ScenarioRunner", "run_scenario",
    "frontend_models", "trace_meta",
    "poisson_trace", "bursty_trace", "diurnal_trace", "flash_crowd_trace",
    "query_trace",
]
