"""CLI: replay a named workload scenario through a serving stack and print
the structured report.

    PYTHONPATH=src python -m repro.workloads.run --scenario poisson --stack frontend
    PYTHONPATH=src python -m repro.workloads.run --scenario stragglers --seed 7
    PYTHONPATH=src python -m repro.workloads.run --scenario poisson --stack lmserver

The report is the shared ``repro.metrics/v1`` schema (DESIGN.md §9):
P50/P95/P99 latency, throughput, SLO-violation rate, cache hit rate,
batch-size and queue-depth distributions, per-model breakdowns, plus the
scenario parameters that produced it. Output is deterministic: the same
seed yields byte-identical JSON.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys

from repro.obs.cli import add_fleet_args, build_fleet, write_fleet
from repro.workloads.scenario import SCENARIOS, ScenarioRunner


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.workloads.run",
        description="Replay a workload scenario and emit a telemetry report.")
    p.add_argument("--scenario", default="poisson", choices=sorted(SCENARIOS),
                   help="named load profile (see DESIGN.md §9)")
    p.add_argument("--stack", default="frontend",
                   choices=("frontend", "lmserver"),
                   help="serving stack to drive")
    p.add_argument("--seed", type=int, default=None,
                   help="override the scenario seed")
    p.add_argument("--duration", type=float, default=None,
                   help="override the trace duration (s)")
    p.add_argument("--rate", type=float, default=None,
                   help="override the mean arrival rate (qps)")
    p.add_argument("--replicas", type=int, default=None,
                   help="override replicas per model (frontend stack)")
    p.add_argument("--report-out", "--out", dest="out", default=None,
                   help="write the JSON report here instead of stdout "
                        "(--out kept as an alias; --report-out is the flag "
                        "shared with python -m repro.cluster.run)")
    p.add_argument("--trace-out", default=None,
                   help="record per-query spans (repro.obs) and write the "
                        "repro.trace/v1 span log here — byte-identical per "
                        "seed; convert with python -m repro.obs.export")
    p.add_argument("--trace-sample-rate", type=float, default=1.0,
                   help="head-based trace sampling rate in [0, 1] "
                        "(default 1.0; only meaningful with --trace-out)")
    add_fleet_args(p)
    return p


def main(argv=None) -> int:
    parser = build_parser()
    args = parser.parse_args(argv)
    overrides = {k: v for k, v in (("seed", args.seed),
                                   ("duration", args.duration),
                                   ("rate", args.rate),
                                   ("replicas", args.replicas))
                 if v is not None}
    # validate before running: the trace generators assert on these, and a
    # bare AssertionError is a bad CLI surface
    sc = dataclasses.replace(SCENARIOS[args.scenario], **overrides)
    if sc.duration <= 0:
        parser.error("--duration must be > 0")
    if sc.rate <= 0:
        parser.error("--rate must be > 0")
    if sc.kind != "poisson" and sc.rate > sc.peak_rate:
        parser.error(f"--rate {sc.rate:g} exceeds the {sc.name!r} scenario's "
                     f"peak rate {sc.peak_rate:g}")
    if sc.replicas < 1:
        parser.error("--replicas must be >= 1")
    tracer = None
    if args.trace_out:
        if not 0.0 <= args.trace_sample_rate <= 1.0:
            parser.error("--trace-sample-rate must be in [0, 1]")
        from repro.obs import Tracer
        tracer = Tracer(sample_rate=args.trace_sample_rate, seed=sc.seed)
    sampler, audit = build_fleet(args, parser)
    text = ScenarioRunner(sc, tracer=tracer, sampler=sampler,
                          audit=audit).run_json(args.stack)
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            f.write(tracer.to_json() + "\n")
    write_fleet(args, sampler, audit)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
