"""Open-loop arrival-trace generators (paper §6 methodology).

Clipper's latency/throughput curves are measured under *open-loop* load:
arrivals come from a stochastic process, not from request/response
round-trips, so queueing delay is visible instead of self-throttled. Four
arrival processes cover the evaluation space:

* ``poisson_trace``       — homogeneous Poisson (Fig 4 steady state)
* ``bursty_trace``        — 2-state Markov-modulated Poisson (burst/lull)
* ``diurnal_trace``       — sinusoidal rate ramp (day/night cycle)
* ``flash_crowd_trace``   — baseline plus a rate spike window

All are deterministic functions of their seed. Inhomogeneous processes use
Lewis-Shedler thinning: candidates at the peak rate, accepted with
probability rate(t)/peak — exact and reproducible.

``query_trace`` attaches query payloads drawn from a finite pool with a
Zipf popularity skew, the regime where the prediction cache (paper §4.2)
matters; ``pool=0`` makes every query unique (cache-defeating).
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np


def poisson_trace(rate: float, duration: float, seed: int = 0,
                  start: float = 0.0) -> np.ndarray:
    """Arrival times of a homogeneous Poisson process on [start, start+duration)."""
    assert rate > 0 and duration > 0
    rng = np.random.default_rng(seed)
    # draw in chunks: E[n] + 6 sigma covers the tail, top up if short
    expected = rate * duration
    chunk = max(16, int(expected + 6.0 * np.sqrt(expected)))
    times: List[float] = []
    t = start
    end = start + duration
    while t < end:
        gaps = rng.exponential(1.0 / rate, size=chunk)
        for g in gaps:
            t += g
            if t >= end:
                break
            times.append(t)
        else:
            continue
        break
    return np.asarray(times, dtype=np.float64)


def bursty_trace(rate_low: float, rate_high: float, duration: float,
                 seed: int = 0, *, mean_dwell_low: float = 0.5,
                 mean_dwell_high: float = 0.1,
                 start: float = 0.0) -> np.ndarray:
    """2-state Markov-modulated Poisson process: exponential dwell times
    alternate between a lull (``rate_low``) and a burst (``rate_high``)."""
    assert 0 < rate_low <= rate_high and duration > 0
    rng = np.random.default_rng(seed)
    times: List[float] = []
    t = start
    end = start + duration
    high = False
    while t < end:
        dwell = rng.exponential(mean_dwell_high if high else mean_dwell_low)
        seg_end = min(t + dwell, end)
        rate = rate_high if high else rate_low
        u = t
        while True:
            u += rng.exponential(1.0 / rate)
            if u >= seg_end:
                break
            times.append(u)
        t = seg_end
        high = not high
    return np.asarray(times, dtype=np.float64)


def _thinned(peak: float, rate_at, duration: float, seed: int,
             start: float) -> np.ndarray:
    """Lewis-Shedler thinning of a peak-rate Poisson process."""
    rng = np.random.default_rng(seed)
    times: List[float] = []
    t = start
    end = start + duration
    while True:
        t += rng.exponential(1.0 / peak)
        if t >= end:
            break
        if rng.random() < rate_at(t - start) / peak:
            times.append(t)
    return np.asarray(times, dtype=np.float64)


def diurnal_trace(rate_min: float, rate_max: float, duration: float,
                  seed: int = 0, *, period: float = None,
                  start: float = 0.0) -> np.ndarray:
    """Sinusoidal rate ramp between ``rate_min`` and ``rate_max`` (one full
    cycle over ``period``, default the whole trace) — the day/night profile
    autoscaling papers (InferLine) evaluate against."""
    assert 0 < rate_min <= rate_max and duration > 0
    period = duration if period is None else period
    mid = (rate_min + rate_max) / 2.0
    amp = (rate_max - rate_min) / 2.0

    def rate_at(t: float) -> float:
        return mid - amp * np.cos(2.0 * np.pi * t / period)

    return _thinned(rate_max, rate_at, duration, seed, start)


def flash_crowd_trace(base_rate: float, spike_rate: float, duration: float,
                      seed: int = 0, *, spike_start: float = None,
                      spike_duration: float = None,
                      start: float = 0.0) -> np.ndarray:
    """Baseline Poisson load with a flash-crowd window at ``spike_rate``
    (default: the middle fifth of the trace)."""
    assert 0 < base_rate <= spike_rate and duration > 0
    spike_start = 0.4 * duration if spike_start is None else spike_start
    spike_duration = (0.2 * duration if spike_duration is None
                      else spike_duration)

    def rate_at(t: float) -> float:
        in_spike = spike_start <= t < spike_start + spike_duration
        return spike_rate if in_spike else base_rate

    return _thinned(spike_rate, rate_at, duration, seed, start)


def query_trace(times: np.ndarray, seed: int = 0, *, d_feat: int = 64,
                pool: int = 0, zipf_a: float = 1.2,
                contexts: int = 1) -> List[Tuple[float, np.ndarray, int]]:
    """Attach payloads to arrival times: ``pool > 0`` draws queries from a
    fixed pool with Zipf(a) popularity (cache-friendly); ``pool = 0`` makes
    every query unique. Returns the frontend's replay format
    ``[(arrival_time, x, context_id)]``."""
    rng = np.random.default_rng(seed)
    n = len(times)
    ctx = (rng.integers(0, contexts, size=n) if contexts > 1
           else np.zeros(n, dtype=np.int64))
    if pool > 0:
        bank = rng.normal(size=(pool, d_feat)).astype(np.float32)
        ranks = np.arange(1, pool + 1, dtype=np.float64) ** (-zipf_a)
        probs = ranks / ranks.sum()
        idx = rng.choice(pool, size=n, p=probs)
        xs = [bank[i] for i in idx]
    else:
        xs = list(rng.normal(size=(n, d_feat)).astype(np.float32))
    return [(float(t), x, int(c)) for t, x, c in zip(times, xs, ctx)]
