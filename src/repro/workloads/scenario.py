"""ScenarioRunner: replay a generated arrival trace through either serving
stack and emit the shared ``repro.metrics/v1`` report.

The named scenarios map to the paper's evaluation (DESIGN.md §9):

* ``poisson``      — steady open-loop load, Fig 4's latency/throughput regime
* ``bursty``       — MMPP burst/lull load, Fig 5's delayed-batching regime
* ``diurnal``      — slow rate ramp (InferLine-style day/night profile)
* ``flash_crowd``  — sudden rate spike: queueing + SLO-violation behaviour
* ``scaling``      — Fig 6: the same load over 1..R replicas
* ``stragglers``   — Fig 9: wide ensemble with injected stragglers; deadline
                     rendering keeps P99 at the SLO while accounting the
                     dropped models

Both stacks run in calibrated-simulation mode (DESIGN.md §8): service times
come from seeded latency models and the clock is virtual, so a scenario is a
pure function of its seed — run it twice, get byte-identical reports.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.containers import linear_latency
from repro.core.frontend import make_clipper
from repro.core.metrics import VirtualClock
from repro.workloads import traces as T

D_FEAT = 64
N_CLASSES = 10


@dataclass(frozen=True)
class Scenario:
    """A reproducible load profile plus the serving configuration it drives."""

    name: str
    kind: str = "poisson"           # poisson | bursty | diurnal | flash_crowd
    rate: float = 400.0             # mean arrival rate (qps)
    peak_rate: float = 1200.0       # bursty/diurnal/flash peak (qps)
    duration: float = 2.0           # trace length (s)
    seed: int = 0
    slo: float = 0.020
    # frontend (Clipper) stack
    ensemble: int = 2               # models in the ensemble
    replicas: int = 1               # replicas per model (Fig 6)
    batch_delay: float = 0.0
    pool: int = 128                 # unique-query pool (0 = all unique)
    p_straggle: float = 0.0         # straggler injection (Fig 9)
    straggle_factor: float = 15.0
    base_latency: float = 0.002     # container latency model: base + per_item*n
    per_item_latency: float = 5e-5
    # lmserver stack
    slots: int = 4
    prompt_len: int = 8
    max_new_tokens: int = 4
    lm_requests: int = 32           # lmserver replays a fixed request count

    def arrival_times(self) -> np.ndarray:
        if self.kind == "poisson":
            return T.poisson_trace(self.rate, self.duration, self.seed)
        if self.kind == "bursty":
            return T.bursty_trace(self.rate, self.peak_rate, self.duration,
                                  self.seed)
        if self.kind == "diurnal":
            return T.diurnal_trace(self.rate, self.peak_rate, self.duration,
                                   self.seed)
        if self.kind == "flash_crowd":
            return T.flash_crowd_trace(self.rate, self.peak_rate,
                                       self.duration, self.seed)
        raise ValueError(f"unknown trace kind: {self.kind}")


SCENARIOS: Dict[str, Scenario] = {
    "poisson": Scenario("poisson"),
    "bursty": Scenario("bursty", kind="bursty", rate=150.0, peak_rate=1500.0),
    "diurnal": Scenario("diurnal", kind="diurnal", rate=100.0,
                        peak_rate=900.0, duration=4.0),
    "flash_crowd": Scenario("flash_crowd", kind="flash_crowd", rate=200.0,
                            peak_rate=2500.0),
    "scaling": Scenario("scaling", rate=900.0, replicas=4,
                        base_latency=0.004, pool=0),
    "stragglers": Scenario("stragglers", rate=250.0, ensemble=4,
                           p_straggle=0.03, pool=0),
    # the prediction-pipeline regime (repro.pipeline, DESIGN.md §12): load
    # near the *accurate* model's saturation point so a cascade matters, a
    # Zipf query pool so the intermediate cache matters
    "pipeline": Scenario("pipeline", rate=300.0, duration=2.0, pool=256,
                         base_latency=0.001, per_item_latency=1e-4,
                         max_new_tokens=8),
}


def trace_meta(scenario: Scenario) -> Dict[str, Any]:
    """Provenance block for the ``repro.metrics/v1`` report: the trace seed
    and generator that produced the run, so an archived report is
    reproducible without the invoking command line."""
    return {
        "trace_seed": scenario.seed,
        "trace_generator": f"{scenario.kind}_trace",
    }


def frontend_models(scenario: Scenario):
    """Deterministic numpy ensemble of graded quality + latency profiles.
    Model i is a fixed linear scorer; its latency model is seeded from
    (scenario.seed, i) so the whole run is a function of the scenario."""
    rng = np.random.default_rng(scenario.seed + 1)
    models, lat = {}, {}
    for i in range(scenario.ensemble):
        W = rng.normal(size=(D_FEAT, N_CLASSES)).astype(np.float32) * 0.1

        def predict(x, W=W):
            z = x @ W
            z = z - z.max(axis=-1, keepdims=True)
            e = np.exp(z)
            return e / e.sum(axis=-1, keepdims=True)

        mid = f"m{i}"
        models[mid] = predict
        lat[mid] = linear_latency(
            scenario.base_latency * (1.0 + 0.3 * i),
            scenario.per_item_latency,
            p_straggle=scenario.p_straggle,
            straggle_factor=scenario.straggle_factor,
            rng=np.random.default_rng(scenario.seed + 1000 + i))
    return models, lat


def sampled_replay(serve, submit, trace, sampler) -> None:
    """Open-loop replay with fleet sampling: the ``replay`` contract, but
    the clock also steps through every sample boundary, so the sampler
    observes the run at its fixed interval even across idle gaps.
    ``serve`` needs ``run`` / ``now`` (settable) / ``pending``;
    ``submit(x, ctx, at)`` issues one query."""
    t = 0.0
    for at, x, ctx in trace:
        while t + sampler.interval <= at:
            t += sampler.interval
            serve.run(until=t)
            if serve.now < t:
                # idle gap: advance the virtual clock so delayed batches
                # see time passing, then dispatch what became ready
                serve.now = t
                serve.run(until=t)
            sampler.sample_until(t)
        serve.run(until=at)
        submit(x, ctx, at)
    while serve.pending:
        t += sampler.interval
        serve.run(until=t)
        if serve.now < t:
            serve.now = t
            serve.run(until=t)
        sampler.sample_until(t)


class ScenarioRunner:
    """Replays one scenario through a serving stack; ``run`` returns the
    shared-schema report dict, ``run_json`` its stable JSON rendering."""

    def __init__(self, scenario: Scenario, *, tracer=None, sampler=None,
                 audit=None):
        """``tracer``: an optional ``repro.obs.Tracer`` threaded into
        whichever stack runs — span logs are byte-identical per seed, like
        the reports. ``sampler`` / ``audit``: optional repro.obs
        ``FleetSampler`` / ``AuditLog``, attached the same way."""
        self.scenario = scenario
        self.tracer = tracer
        self.sampler = sampler
        self.audit = audit

    # -- frontend (discrete-event Clipper) ------------------------------
    def run_frontend(self) -> Dict[str, Any]:
        s = self.scenario
        models, lat = frontend_models(s)
        clip = make_clipper(models, "exp4", slo=s.slo,
                            replicas=s.replicas, latency_models=lat,
                            batch_delay=s.batch_delay, seed=s.seed,
                            tracer=self.tracer, audit=self.audit)
        trace = T.query_trace(s.arrival_times(), s.seed, d_feat=D_FEAT,
                              pool=s.pool)
        if self.sampler is not None:
            self.sampler.bind(metrics=clip.metrics, tracer=self.tracer)
            self.sampler.add_probe(clip.timeseries_probe)
            sampled_replay(clip, lambda x, ctx, at: clip.submit(
                x, context_id=ctx, arrival_time=at), trace, self.sampler)
        else:
            clip.replay(trace)
        return clip.report()

    # -- lmserver (continuous batching) ---------------------------------
    def build_lmserver(self, *, admission=None):
        """Construct the calibrated-simulation LMServer for this scenario.
        Returns ``(srv, clock, params, pending)`` where ``pending`` is the
        arrival list ``[(time, prompt)]`` — the control-plane driver reuses
        this to run the same stack with admission control in front."""
        import jax

        from repro.configs.registry import ARCHITECTURES, reduced_config
        from repro.distributed.sharding import serve_rules
        from repro.launch.mesh import make_local_mesh
        from repro.models.api import build_model
        from repro.serving.engine import LMServer

        s = self.scenario
        mesh = make_local_mesh()
        rules = serve_rules(multi_pod=False)
        cfg = reduced_config(ARCHITECTURES["smollm-360m"], num_layers=2,
                             d_model=64)
        model = build_model(cfg, mesh, rules)
        params = model.init(jax.random.PRNGKey(s.seed))

        def service_model(kind: str, batch: int, tokens: int) -> float:
            if kind == "prefill":
                return s.base_latency + s.per_item_latency * batch * tokens
            return s.base_latency / 4 + s.per_item_latency * batch

        clock = VirtualClock()
        srv = LMServer(model, mesh, rules, slots=s.slots, max_len=64,
                       slo=s.slo, temperature=0.0, seed=s.seed,
                       clock=clock, service_model=service_model,
                       model_id=cfg.name, admission_control=admission,
                       tracer=self.tracer, audit=self.audit)
        rng = np.random.default_rng(s.seed)
        # open-loop arrivals, thinned to a fixed request count so CLI runs
        # stay cheap; the arrival *process* is the scenario's
        times = self.scenario.arrival_times()[:s.lm_requests]
        if len(times) == 0:
            times = np.asarray([0.0])
        pending: List[Tuple[float, np.ndarray]] = [
            (float(t), rng.integers(0, cfg.vocab_size, size=s.prompt_len))
            for t in times]
        return srv, clock, params, pending

    def run_lmserver(self, *, admission=None) -> Dict[str, Any]:
        """Calibrated simulation: a tiny real model decodes for real, but
        service times come from a seeded latency model through a virtual
        clock — deterministic end to end."""
        s = self.scenario
        srv, clock, params, pending = self.build_lmserver(admission=admission)
        if self.sampler is not None:
            self.sampler.bind(metrics=srv.metrics, tracer=self.tracer)
            self.sampler.add_probe(srv.timeseries_probe)
        i = 0
        while i < len(pending) or srv.pending:
            # release arrivals up to the virtual now
            while i < len(pending) and pending[i][0] <= clock.now:
                at, prompt = pending[i]
                srv.submit(prompt, max_new_tokens=s.max_new_tokens, now=at)
                i += 1
            if not srv.pending and i < len(pending):
                clock.advance(pending[i][0] - clock.now)   # idle: jump ahead
                if self.sampler is not None:
                    self.sampler.sample_until(clock.now)
                continue
            srv.step(params)
            if self.sampler is not None:
                self.sampler.sample_until(clock.now)
        return srv.report()

    # -- entry points ---------------------------------------------------
    def run(self, stack: str = "frontend") -> Dict[str, Any]:
        if stack == "frontend":
            rep = self.run_frontend()
        elif stack == "lmserver":
            rep = self.run_lmserver()
        else:
            raise ValueError(f"unknown stack: {stack}")
        rep["scenario"] = dataclasses.asdict(self.scenario)
        rep["meta"] = trace_meta(self.scenario)
        return rep

    def run_json(self, stack: str = "frontend") -> str:
        import json
        return json.dumps(self.run(stack), sort_keys=True, indent=2)


def run_scenario(name: str, stack: str = "frontend", *, tracer=None,
                 sampler=None, audit=None,
                 **overrides: Any) -> Dict[str, Any]:
    """Convenience: look up a named scenario, apply overrides, run it."""
    if name not in SCENARIOS:
        raise KeyError(
            f"unknown scenario {name!r}; have {sorted(SCENARIOS)}")
    sc = dataclasses.replace(SCENARIOS[name], **overrides)
    return ScenarioRunner(sc, tracer=tracer, sampler=sampler,
                          audit=audit).run(stack)
