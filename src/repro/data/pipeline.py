"""Deterministic, seekable, host-shardable data pipeline.

Training restarts must be bit-exact: batch t is a pure function of
(seed, step, host_shard), so resuming from a checkpoint at step k replays
exactly the batches k, k+1, ... with no iterator state to persist. Synthetic
LM data comes from a counter-based generator (threefry via jax on host
numpy is too slow at scale — we use a splitmix64-style hash, vectorized)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _hash_tokens(seed: int, step: int, shard: int, n: int, vocab: int,
                 salt: int = 0) -> np.ndarray:
    base = (np.uint64(seed) << np.uint64(40)) ^ (np.uint64(step) << np.uint64(16)) \
        ^ np.uint64(shard) ^ (np.uint64(salt) << np.uint64(56))
    idx = np.arange(n, dtype=np.uint64) + (base << np.uint64(1))
    with np.errstate(over="ignore"):
        h = _splitmix64(idx)
    return (h % np.uint64(vocab)).astype(np.int32)


@dataclasses.dataclass
class SyntheticLMData:
    """Markov-flavored synthetic token stream: next token depends on the
    previous one (so a trained model shows decreasing loss — used by the
    example train driver), with a deterministic seekable layout."""

    cfg: ModelConfig
    shape: ShapeSpec
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    structure: float = 0.75    # P(next = f(prev)); rest uniform

    def batch_at(self, step: int) -> Dict[str, Any]:
        B = self.shape.global_batch // self.num_hosts
        S = self._text_len()
        V = self.cfg.vocab_size
        raw = _hash_tokens(self.seed, step, self.host_id, B * (S + 1), V)
        raw = raw.reshape(B, S + 1)
        gate = _hash_tokens(self.seed, step, self.host_id, B * (S + 1), 1_000_000,
                            salt=1).reshape(B, S + 1)
        toks = raw.copy()
        for t in range(1, S + 1):  # vectorized over batch
            structured = (toks[:, t - 1] * 31 + 7) % V
            use = gate[:, t] < int(self.structure * 1_000_000)
            toks[:, t] = np.where(use, structured, raw[:, t])
        batch = {"tokens": toks[:, :S].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        return self._add_frontends(batch, step, B, S)

    def _text_len(self) -> int:
        S = self.shape.seq_len
        if self.cfg.is_encoder_decoder:
            return S // self.cfg.decoder_ratio
        if self.cfg.frontend == "vision":
            return S - self.cfg.num_prefix_embeddings
        return S

    def _add_frontends(self, batch, step, B, S):
        d = self.cfg.d_model
        if self.cfg.is_encoder_decoder:
            n = B * self.shape.seq_len * d
            h = _hash_tokens(self.seed, step, self.host_id, n, 1 << 16, salt=2)
            batch["frames"] = ((h.reshape(B, self.shape.seq_len, d).astype(np.float32)
                                / (1 << 15)) - 1.0) * 0.02
        if self.cfg.frontend == "vision":
            P = self.cfg.num_prefix_embeddings
            h = _hash_tokens(self.seed, step, self.host_id, B * P * d, 1 << 16,
                             salt=3)
            batch["prefix_embeddings"] = (
                (h.reshape(B, P, d).astype(np.float32) / (1 << 15)) - 1.0) * 0.02
        return batch

    def iterator(self, start_step: int = 0) -> Iterator[Dict[str, Any]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def data_iter(cfg: ModelConfig, shape: ShapeSpec, *, seed: int = 0,
              start_step: int = 0, num_hosts: int = 1, host_id: int = 0):
    return SyntheticLMData(cfg, shape, seed, num_hosts, host_id
                           ).iterator(start_step)
