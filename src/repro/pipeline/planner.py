"""Per-stage SLO splitting for prediction pipelines (DESIGN.md §12).

InferLine's observation: a pipeline served under one end-to-end SLO needs
that SLO *divided* across stages, so each stage's admission control and
adaptive batching optimize against the share it actually has — not the whole
budget. The splitter here is the deterministic proportional rule:

    share(s)   = slo * est(s) / critical_path
    prefix(s)  = slo * longest_path_through(s) / critical_path

where ``est(s)`` is the stage's expected service time (max over its models'
observed per-query service, fan-out within a stage runs in parallel) and
``critical_path`` is the longest root-to-leaf path by ``est``. Properties
(tested in tests/test_pipeline.py):

* along any root-to-leaf path the shares sum to <= slo (the critical path
  sums to exactly slo);
* share(s) is monotone non-decreasing in est(s);
* prefix(output) == slo, so the pipeline deadline is exactly the query SLO.

The executor feeds ``prefix(s)`` into stage deadlines (admission control
slack) and ``share(s)`` into each stage's AIMD latency budget, and replans
periodically from live ``ReplicaSet`` stats as service estimates converge.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Optional

from repro.pipeline.graph import PipelineGraph

# floor for a stage's service estimate: keeps the split defined before any
# stats exist (all-equal estimates -> equal split by critical-path depth)
MIN_EST = 1e-6


@dataclass(frozen=True)
class SloSplit:
    """One deterministic division of a pipeline SLO across stages."""

    slo: float
    shares: Dict[str, float]       # per-stage latency budget
    prefix: Dict[str, float]       # absolute offset of the stage's deadline
    critical_path_s: float         # longest path by service estimate

    def describe(self) -> Dict[str, object]:
        return {
            "slo": self.slo,
            "critical_path_s": self.critical_path_s,
            "shares": {k: self.shares[k] for k in sorted(self.shares)},
            "prefix": {k: self.prefix[k] for k in sorted(self.prefix)},
        }


def stage_estimates(graph: PipelineGraph, replica_sets: Mapping[str, object],
                    default: float = 1e-3) -> Dict[str, float]:
    """Expected service seconds per stage from live per-replica stats: the
    max over the stage's models of ``ReplicaSet.mean_service`` (fan-out
    within a stage evaluates in parallel, so the slowest member binds).
    Pure combine stages cost nothing (MIN_EST)."""
    out: Dict[str, float] = {}
    for name in graph.order:
        stage = graph.stages[name]
        ests = [replica_sets[mid].mean_service(default)
                for mid in stage.model_ids if mid in replica_sets]
        out[name] = max([e for e in ests if e > 0.0] or [MIN_EST])
    return out


def split_slo(graph: PipelineGraph, slo: float,
              est: Optional[Mapping[str, float]] = None) -> SloSplit:
    """Divide ``slo`` across the graph's stages proportionally to service
    estimates along the critical path (module docstring)."""
    assert slo > 0.0
    e = {n: max(float((est or {}).get(n, MIN_EST)), MIN_EST)
         for n in graph.order}
    finish: Dict[str, float] = {}
    for n in graph.order:               # topo order: parents precede children
        start = max((finish[p] for p in graph.stages[n].parents), default=0.0)
        finish[n] = start + e[n]
    critical = max(finish.values())
    shares = {n: slo * e[n] / critical for n in graph.order}
    prefix = {n: slo * finish[n] / critical for n in graph.order}
    # the output stage's deadline is the query deadline even when it is not
    # on the critical path (every path must resolve by the pipeline SLO)
    prefix[graph.output] = slo
    return SloSplit(slo=slo, shares=shares, prefix=prefix,
                    critical_path_s=critical)
