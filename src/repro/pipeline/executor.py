"""PipelineExecutor: serve DAG pipelines on the event-driven Clipper
frontend (DESIGN.md §12).

Each pipeline query walks the graph stage by stage. A stage becomes ready
when every parent has resolved; its gate (if any) may skip it outright
(cascade short-circuit), otherwise its models are submitted as one *stage
job* through ``Clipper.submit_stage`` — which means every existing layer
applies per stage:

* the **prediction cache** doubles as the pipeline's intermediate-result
  cache: stage inputs are digested like any query, so a shared prefix
  (same model, same stage input) is computed once across queries *and*
  across pipelines — the dataflow-caching effect (Sreekanti et al.);
* **admission control** sees per-stage deadlines carved from the pipeline
  SLO by the planner (``SloSplit.prefix``), so a stage whose share is
  already unmeetable sheds early instead of poisoning downstream stages;
* **adaptive batching** per stage model runs against the stage's *share*
  of the SLO (``SloSplit.shares`` feeds each AIMD controller), not the
  whole budget;
* **straggler mitigation** fires per stage: at the stage deadline the
  combine runs with whatever ensemble members arrived.

Completion, latency, and SLO attainment are accounted at *pipeline*
granularity in the shared ``repro.metrics/v1`` schema — a pipeline query
counts once no matter how many stage jobs it fanned into.
"""

from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import metrics as M
from repro.core.batching import AIMDController
from repro.core.containers import JaxModelContainer, ReplicaSet
from repro.core.frontend import Clipper
from repro.core.interfaces import Prediction
from repro.core.selection import Exp4Policy
from repro.core.straggler import record_stragglers
from repro.pipeline.graph import PipelineGraph, Stage
from repro.pipeline.planner import SloSplit, split_slo, stage_estimates


class PipelineExecutor:
    """Drives one ``PipelineGraph`` over Clipper's event loop."""

    def __init__(self, graph: PipelineGraph, models: Dict[str, Callable], *,
                 slo: float = 0.020, latency_models: Optional[Dict] = None,
                 replicas: int = 1, batch_delay: float = 0.0,
                 cache_size: int = 4096, use_cache: bool = True,
                 seed: int = 0, admission=None, router=None,
                 metrics=None, service_priors: Optional[Dict[str, float]] = None,
                 replan_every: int = 64, aimd_kwargs: Optional[dict] = None,
                 tracer=None, audit=None):
        self.graph = graph
        self.slo = slo
        # span tracing (repro.obs, DESIGN.md §13): the tracer is shared
        # with the underlying Clipper, so stage jobs' queue/service spans
        # nest under the stage spans opened here
        self.tracer = tracer
        self.replan_every = replan_every
        missing = [m for m in graph.model_ids() if m not in models]
        if missing:
            raise ValueError(f"graph references unknown models {missing}")
        # initial split from priors (or the uniform fallback); each stage
        # model's AIMD controller gets the *stage's* latency budget
        priors = {n: max((service_priors or {}).get(mid, 0.0)
                         for mid in graph.stages[n].model_ids or ("",))
                  for n in graph.order}
        self.split: SloSplit = split_slo(graph, slo, priors)
        self.stage_of: Dict[str, str] = {}
        for n in graph.order:
            for mid in graph.stages[n].model_ids:
                self.stage_of.setdefault(mid, n)
        aimd_kwargs = aimd_kwargs or {}
        sets: Dict[str, ReplicaSet] = {}
        for mid in graph.model_ids():
            lm = (latency_models or {}).get(mid)
            reps = [JaxModelContainer(mid, models[mid], latency_model=lm)
                    for _ in range(replicas)]
            # the factory reads the *live* split, so replicas the autoscaler
            # adds mid-run batch against the current stage share, not the
            # prior-based share frozen at construction
            sets[mid] = ReplicaSet(
                reps,
                (lambda mid=mid: AIMDController(
                    self.split.shares[self.stage_of[mid]], **aimd_kwargs)),
                batch_delay)
        self.clip = Clipper(sets, Exp4Policy(sorted(sets)), slo=slo,
                            cache_size=cache_size, use_cache=use_cache,
                            seed=seed, metrics=metrics, router=router,
                            admission=admission, tracer=tracer, audit=audit)
        self.metrics = self.clip.metrics
        self._pseq = itertools.count()
        self._inflight: Dict[int, dict] = {}
        self.results: Dict[int, Prediction] = {}
        self.shed_qids: set = set()
        self._since_replan = 0
        self.replans = 0

    # ------------------------------------------------------------------
    # application API
    # ------------------------------------------------------------------
    def submit(self, x, *, arrival_time: Optional[float] = None) -> int:
        """Issue one pipeline query; returns the pipeline query id."""
        at = self.clip.now if arrival_time is None else arrival_time
        self.clip.now = max(self.clip.now, at)
        self._since_replan += 1
        if self._since_replan >= self.replan_every:
            self.replan()
        pid = next(self._pseq)
        self.metrics.inc(M.QUERIES_SUBMITTED)
        self.metrics.mark(at)
        trace = None
        if self.tracer is not None:
            # root span: the whole pipeline walk; budget = the full SLO
            trace = self.tracer.start_trace(
                "pipeline", "pipeline", at, budget_s=self.slo,
                attrs={"pid": pid})
        entry = {"x": x, "arrival": at, "outputs": {}, "done_stages": set(),
                 "launched": set(), "prefix": dict(self.split.prefix),
                 "done": False, "trace": trace,
                 "stage_spans": {}, "stage_times": {}}
        self._inflight[pid] = entry
        for stage in self.graph.roots():
            entry["launched"].add(stage.name)
            self._launch_stage(pid, stage)
        return pid

    def run(self, until: Optional[float] = None) -> None:
        self.clip.run(until=until)

    def replay(self, trace: Sequence[Tuple[float, Any, int]]) -> List[int]:
        """Open-loop replay of ``[(arrival_time, x, context_id)]`` — the
        same contract as ``Clipper.replay``."""
        pids = []
        for at, x, _ctx in trace:
            self.run(until=at)
            pids.append(self.submit(x, arrival_time=at))
        self.run()
        return pids

    @property
    def now(self) -> float:
        return self.clip.now

    @property
    def pending(self) -> bool:
        return self.clip.pending

    @property
    def replica_sets(self) -> Dict[str, ReplicaSet]:
        return self.clip.replica_sets

    def timeseries_probe(self, now: float, dt: float) -> Dict[str, float]:
        """FleetSampler probe: the underlying frontend's fleet series plus
        pipeline-level state — in-flight pipeline walks and the planner's
        live per-stage SLO shares (repro.obs.timeseries, DESIGN.md §15)."""
        out = self.clip.timeseries_probe(now, dt)
        out["pipeline.inflight"] = float(len(self._inflight))
        for name, share in sorted(self.split.shares.items()):
            out[f"pipeline.slo_share.{name}"] = share
        return out

    # ------------------------------------------------------------------
    # planning
    # ------------------------------------------------------------------
    def replan(self) -> SloSplit:
        """Recompute the SLO split from live service stats and point every
        stage's AIMD controllers at their new share. In-flight queries keep
        the prefix they were admitted under (their stage deadlines already
        exist as events); new queries use the new split. Deterministic: a
        pure function of the run so far."""
        self._since_replan = 0
        est = stage_estimates(self.graph, self.clip.replica_sets)
        self.split = split_slo(self.graph, self.slo, est)
        for mid, rs in self.clip.replica_sets.items():
            share = self.split.shares[self.stage_of[mid]]
            for queue in rs.queues:
                queue.controller.slo = share
        self.replans += 1
        return self.split

    # ------------------------------------------------------------------
    # stage machinery
    # ------------------------------------------------------------------
    def _launch_stage(self, pid: int, stage: Stage) -> None:
        entry = self._inflight[pid]
        # stage clock-in: launches are synchronous at the last parent's
        # resolution, so chained (start, end) pairs tile the pipeline's
        # critical path exactly — the attribution walk relies on this
        if entry.get("trace") is not None:
            entry["stage_times"][stage.name] = self.clip.now
        outs = {p: entry["outputs"][p] for p in stage.parents}
        if stage.gate is not None:
            if not stage.gate(outs):
                self.metrics.inc(M.PIPELINE_STAGES_SKIPPED)
                if entry.get("trace") is not None:
                    self.tracer.event(entry["trace"], f"skip:{stage.name}",
                                      "pipeline.gate", self.clip.now)
                self._stage_done(pid, stage, None)
                return
            self.metrics.inc(M.PIPELINE_ESCALATIONS)
            if entry.get("trace") is not None:
                self.tracer.event(entry["trace"], f"escalate:{stage.name}",
                                  "pipeline.gate", self.clip.now)
        xin = stage.prepare_input(entry["x"], outs)
        if not stage.model_ids:
            # pure combine node: resolves synchronously, costs nothing
            self._stage_done(pid, stage,
                             stage.combine_preds(xin, {}, outs))
            return
        self.metrics.inc(M.PIPELINE_STAGE_JOBS)
        deadline = entry["arrival"] + entry["prefix"][stage.name]
        span = None
        if entry.get("trace") is not None:
            # stage span budget: this stage's slice of the prefix deadlines
            # the query was admitted under (the planner's share at submit)
            budget = entry["prefix"][stage.name] - max(
                [entry["prefix"].get(p, 0.0) for p in stage.parents]
                or [0.0])
            span = self.tracer.start_span(
                entry["trace"], stage.name, "pipeline.stage", self.clip.now,
                budget_s=budget, attrs={"models": list(stage.model_ids)})
            entry["stage_spans"][stage.name] = span

        def finalize(preds, missing, at_deadline,
                     pid=pid, stage=stage, xin=xin, outs=outs):
            record_stragglers(self.metrics, missing)
            if not preds and missing:
                # every model of the stage was lost (crashed replicas,
                # exhausted retries — DESIGN.md §14): the pipeline degrades
                # to a shed downstream, but the fault is accounted here
                self.metrics.inc(M.PIPELINE_STAGES_FAILED)
            y = (stage.combine_preds(xin, preds, outs) if preds else None)
            self._stage_done(pid, stage, y)

        self.clip.submit_stage(stage.model_ids, xin, deadline=deadline,
                               finalize=finalize, trace_parent=span)

    def _stage_done(self, pid: int, stage: Stage, y: Any) -> None:
        entry = self._inflight[pid]
        if entry.get("trace") is not None:
            start = entry["stage_times"].get(stage.name, self.clip.now)
            entry["stage_times"][stage.name] = (start, self.clip.now)
            span = entry["stage_spans"].pop(stage.name, None)
            if span is not None:
                self.tracer.end_span(span, self.clip.now, empty=y is None)
        entry["outputs"][stage.name] = y
        entry["done_stages"].add(stage.name)
        if stage.name == self.graph.output:
            self._complete(pid, y)
            return
        for child in self.graph.children(stage.name):
            if (child.name not in entry["launched"]
                    and all(p in entry["done_stages"]
                            for p in child.parents)):
                entry["launched"].add(child.name)
                self._launch_stage(pid, child)

    def _complete(self, pid: int, y: Any) -> None:
        entry = self._inflight.pop(pid)
        entry["done"] = True
        if y is None:
            # every tier shed or straggled away: the pipeline has no answer
            self.metrics.inc(M.QUERIES_SHED)
            self.shed_qids.add(pid)
            if entry.get("trace") is not None:
                self.tracer.end_trace(entry["trace"], self.clip.now,
                                      status="shed")
            return
        latency = self.clip.now - entry["arrival"]
        if entry.get("trace") is not None:
            self._end_pipeline_trace(entry, latency)
        self.metrics.mark(self.clip.now)
        self.metrics.inc(M.QUERIES_COMPLETED)
        self.metrics.observe_latency(latency)
        conf = float(y.get("confidence", 1.0)) if isinstance(y, dict) else 1.0
        self.results[pid] = Prediction(pid, y, conf, latency=latency)

    def _end_pipeline_trace(self, entry: dict, latency: float) -> None:
        """Exact latency attribution (DESIGN.md §13): walk the critical
        path backwards from the output stage, at each step following the
        parent that resolved last. Stage launches are synchronous at the
        last parent's resolution, so the chained stage durations partition
        ``latency`` exactly — one ``pipeline.stage.<name>`` component per
        critical stage, fractions summing to 1."""
        attribution = None
        if latency > 0:
            times = entry["stage_times"]
            attribution = {}
            name = self.graph.output
            while name is not None:
                start, end = times[name]
                comp = f"pipeline.stage.{name}"
                attribution[comp] = attribution.get(comp, 0.0) + (end - start)
                parents = [p for p in self.graph.stages[name].parents
                           if isinstance(times.get(p), tuple)]
                name = (max(parents, key=lambda p: (times[p][1], p))
                        if parents else None)
        self.tracer.end_trace(entry["trace"], self.clip.now,
                              attribution=attribution)

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        """Shared-schema report plus a ``pipeline`` section (graph shape,
        live SLO split, stage-job accounting); with a tracer attached it
        also gains ``latency_attribution`` and a ``trace`` summary (same
        contract as ``Clipper.report``)."""
        rep = self.metrics.report("pipeline")
        dur = self.metrics.duration
        per_model = rep.get("per_model") or {}
        for mid, rs in sorted(self.replica_sets.items()):
            row = per_model.get(mid)
            if row is None:
                continue
            # per-replica busy-time / wall-time, as in Clipper.report
            row["replicas"] = [
                {"replica": st["replica"],
                 "busy_time": st["busy_time"],
                 "utilization": st["busy_time"] / dur if dur > 0 else 0.0,
                 "queries": st["queries"],
                 "retired": st["retired"]}
                for st in rs.replica_stats()]
        jobs = self.metrics.counter(M.PIPELINE_STAGE_JOBS)
        skipped = self.metrics.counter(M.PIPELINE_STAGES_SKIPPED)
        escalated = self.metrics.counter(M.PIPELINE_ESCALATIONS)
        gated = skipped + escalated
        rep["pipeline"] = {
            "graph": self.graph.describe(),
            "slo_split": self.split.describe(),
            "replans": self.replans,
            "stage_jobs": jobs,
            "stages_skipped": skipped,
            "escalations": escalated,
            "escalation_rate": (escalated / gated) if gated else 0.0,
            # stage-level admission actions (``admission.shed/degraded``
            # stay pipeline-granular: one per query)
            "stages_shed": self.metrics.counter(M.PIPELINE_STAGES_SHED),
            "stages_degraded": self.metrics.counter(
                M.PIPELINE_STAGES_DEGRADED),
            "stages_failed": self.metrics.counter(
                M.PIPELINE_STAGES_FAILED),
        }
        if self.tracer is not None:
            rep["latency_attribution"] = self.tracer.attribution_report()
            rep["trace"] = self.tracer.summary()
        return rep

    def report_json(self, **extra: Any) -> str:
        import json
        rep = self.report()
        rep.update(extra)
        return json.dumps(rep, sort_keys=True, indent=2)
