"""Prediction pipelines (DESIGN.md §12): DAG composition of model
containers and LM engines, served end-to-end under one SLO.

* ``graph``    — ``PipelineGraph`` / ``Stage`` spec with fan-out, fan-in,
  and gated (cascade) stages; canonical builders ``cascade_graph`` and
  ``fanout_graph``;
* ``planner``  — InferLine-style per-stage SLO splitting from observed
  service stats (``split_slo``), feeding stage deadlines into admission
  control and stage shares into the AIMD batching controllers;
* ``executor`` — ``PipelineExecutor`` on the event-driven Clipper frontend,
  with the prediction cache reused as the intermediate-result cache;
* ``cascade``  — ``LMCascade``: draft-then-verify across two LM engines;
* ``scenario`` / ``run`` — named pipeline presets and the deterministic
  ``python -m repro.pipeline.run`` CLI (byte-identical reports per seed).
"""

from repro.pipeline.cascade import (LMCascade, distinct_token_confidence,
                                    make_escalate)
from repro.pipeline.executor import PipelineExecutor
from repro.pipeline.graph import (PipelineGraph, Stage, agreement_combine,
                                  cascade_graph, fanout_graph)
from repro.pipeline.planner import (MIN_EST, SloSplit, split_slo,
                                    stage_estimates)
from repro.pipeline.scenario import (CASCADE_THRESHOLD, build_executor,
                                     build_graph, pipeline_models,
                                     pipeline_replica_factory,
                                     pipeline_scenario, run_lmcascade,
                                     run_pipeline)

__all__ = [
    "LMCascade", "distinct_token_confidence", "make_escalate",
    "PipelineExecutor",
    "PipelineGraph", "Stage", "agreement_combine", "cascade_graph",
    "fanout_graph",
    "MIN_EST", "SloSplit", "split_slo", "stage_estimates",
    "CASCADE_THRESHOLD", "build_executor", "build_graph", "pipeline_models",
    "pipeline_replica_factory", "pipeline_scenario", "run_lmcascade",
    "run_pipeline",
]
