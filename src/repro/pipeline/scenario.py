"""Named pipeline scenarios: deterministic model sets + graph shapes wired
to the shared workload traces (DESIGN.md §12).

``pipeline_models`` builds a graded model zoo from a scenario seed:

* ``prep``     — feature normalizer (cheap, every pipeline's root);
* ``cheap0/1`` — noisy draft scorers (fast, disagree on hard queries);
* ``accurate`` — near-oracle scorer (slow — the model a monolithic
                 deployment would serve everything with).

All quality is relative to one hidden true scorer, so draft *disagreement*
(``agreement_confidence``) genuinely correlates with being wrong — the
cascade escalates exactly the queries worth escalating. Latency models are
seeded per (scenario, model), so every run is a pure function of the
scenario (calibrated simulation, DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.containers import JaxModelContainer, linear_latency
from repro.pipeline.executor import PipelineExecutor
from repro.pipeline.graph import PipelineGraph, cascade_graph, fanout_graph
from repro.workloads import traces as T
from repro.workloads.scenario import (D_FEAT, N_CLASSES, SCENARIOS, Scenario,
                                      sampled_replay, trace_meta)

# cascade gate: 2 draft models agree (confidence 1.0) or split (0.5);
# anything below this escalates, so the threshold means "escalate on any
# draft disagreement"
CASCADE_THRESHOLD = 0.75

# cost shape of the zoo relative to Scenario.base_latency — the accurate
# model is an order of magnitude hotter than a draft member, and its
# per-item cost actually binds under batching (so a monolithic deployment
# saturates where the cascade still has headroom)
COSTS: Dict[str, Tuple[float, float]] = {
    # model -> (base multiplier, per-item multiplier) on the scenario's
    # (base_latency, per_item_latency)
    "prep": (0.25, 0.5),
    "cheap0": (1.0, 1.0),
    "cheap1": (1.0, 1.0),
    "accurate": (4.0, 30.0),
}

# draft scorers see the truth through this much weight noise; accurate sees
# almost none — tuned so drafts disagree on ~10-20% of queries
DRAFT_NOISE = 0.15
ACCURATE_NOISE = 0.05


def _softmax(z: np.ndarray) -> np.ndarray:
    z = z - z.max(axis=-1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=-1, keepdims=True)


def _scorer(W: np.ndarray) -> Callable:
    def predict(x: np.ndarray) -> np.ndarray:
        return _softmax(x @ W)
    return predict


def pipeline_models(scenario: Scenario):
    """(models, latency_models, service_priors, label_fn) for a scenario.

    ``label_fn`` maps raw features to the hidden true class — benchmarks
    use it to score cascade vs monolithic accuracy."""
    rng = np.random.default_rng([scenario.seed, 31337])
    W_true = rng.normal(size=(D_FEAT, N_CLASSES)).astype(np.float32) * 0.2

    def prep(x: np.ndarray) -> np.ndarray:
        n = np.linalg.norm(x, axis=-1, keepdims=True)
        return (x / np.maximum(n, 1e-6)) * np.sqrt(x.shape[-1])

    models: Dict[str, Callable] = {"prep": prep}
    noises = {"cheap0": DRAFT_NOISE, "cheap1": DRAFT_NOISE,
              "accurate": ACCURATE_NOISE}
    for mid, noise in noises.items():
        Wm = W_true + noise * rng.normal(
            size=W_true.shape).astype(np.float32) * 0.2
        models[mid] = _scorer(Wm)

    lat: Dict[str, Any] = {}
    priors: Dict[str, float] = {}
    for i, mid in enumerate(sorted(COSTS)):
        base_m, item_m = COSTS[mid]
        lat[mid] = linear_latency(
            scenario.base_latency * base_m,
            scenario.per_item_latency * item_m,
            p_straggle=scenario.p_straggle,
            straggle_factor=scenario.straggle_factor,
            rng=np.random.default_rng([scenario.seed, 5000 + i]))
        priors[mid] = (scenario.base_latency * base_m
                       + scenario.per_item_latency * item_m)

    def label_fn(x: np.ndarray) -> np.ndarray:
        return np.argmax(prep(x) @ W_true, axis=-1)

    return models, lat, priors, label_fn


def pipeline_replica_factory(scenario: Scenario, models: Dict[str, Callable]):
    """Deterministic fresh-replica supplier for per-stage autoscaling:
    replica k of model ``mid`` draws its latency stream from seed
    (scenario.seed, model index, k) — the ``cluster.plan.replica_factory``
    contract for the pipeline zoo."""
    ids = sorted(COSTS)
    counters: Dict[str, int] = {}

    def make(mid: str) -> JaxModelContainer:
        k = counters.get(mid, 0)
        counters[mid] = k + 1
        i = ids.index(mid)
        base_m, item_m = COSTS[mid]
        latm = linear_latency(
            scenario.base_latency * base_m,
            scenario.per_item_latency * item_m,
            p_straggle=scenario.p_straggle,
            straggle_factor=scenario.straggle_factor,
            rng=np.random.default_rng([scenario.seed, 8000 + i, k]))
        return JaxModelContainer(mid, models[mid], latency_model=latm)

    return make


# ---------------------------------------------------------------------------
# named pipeline presets
# ---------------------------------------------------------------------------

def build_graph(kind: str, *, threshold: float = CASCADE_THRESHOLD
                ) -> PipelineGraph:
    if kind == "cascade":
        return cascade_graph(("cheap0", "cheap1"), "accurate",
                             preprocess_model="prep", threshold=threshold)
    if kind == "fanout":
        return fanout_graph(("cheap0", "cheap1", "accurate"),
                            preprocess_model="prep")
    raise KeyError(f"unknown pipeline graph {kind!r}; "
                   f"have ['cascade', 'fanout']")


def build_executor(scenario: Scenario, kind: str = "cascade", *,
                   threshold: float = CASCADE_THRESHOLD,
                   admission=None, router=None, use_cache: bool = True,
                   zoo=None, tracer=None, audit=None) -> PipelineExecutor:
    """``zoo``: a prebuilt ``pipeline_models(scenario)`` tuple, so callers
    that also need the models (replica factories) construct them once."""
    models, lat, priors, _ = zoo if zoo is not None else \
        pipeline_models(scenario)
    return PipelineExecutor(
        build_graph(kind, threshold=threshold), models,
        slo=scenario.slo, latency_models=lat, replicas=scenario.replicas,
        batch_delay=scenario.batch_delay, seed=scenario.seed,
        service_priors=priors, admission=admission, router=router,
        use_cache=use_cache, tracer=tracer, audit=audit)


def run_pipeline(scenario: Scenario, kind: str = "cascade", *,
                 threshold: float = CASCADE_THRESHOLD,
                 use_cache: bool = True, tracer=None, sampler=None,
                 audit=None) -> Dict[str, Any]:
    """Replay the scenario's trace through a pipeline and report — the
    pipeline counterpart of ``ScenarioRunner.run`` (byte-identical JSON per
    seed). ``sampler`` / ``audit``: optional repro.obs collectors."""
    ex = build_executor(scenario, kind, threshold=threshold,
                        use_cache=use_cache, tracer=tracer, audit=audit)
    trace = T.query_trace(scenario.arrival_times(), scenario.seed,
                          d_feat=D_FEAT, pool=scenario.pool)
    if sampler is not None:
        sampler.bind(metrics=ex.metrics, tracer=tracer)
        sampler.add_probe(ex.timeseries_probe)
        sampled_replay(ex.clip,
                       lambda x, ctx, at: ex.submit(x, arrival_time=at),
                       trace, sampler)
    else:
        ex.replay(trace)
    rep = ex.report()
    rep["scenario"] = dataclasses.asdict(scenario)
    rep["meta"] = trace_meta(scenario)
    return rep


def run_lmcascade(scenario: Scenario, *, threshold: float = 0.9,
                  draft_admission=None, verify_admission=None,
                  tracer=None, sampler=None, audit=None) -> Dict[str, Any]:
    """Draft-then-verify across two calibrated-simulation LM engines: the
    draft engine decodes every prompt with a cheap service model; drafts
    that fail the distinct-token confidence check re-decode on the verify
    engine (4x the service cost). Deterministic per seed."""
    import jax

    from repro.configs.registry import ARCHITECTURES, reduced_config
    from repro.core.metrics import MetricsRegistry, VirtualClock
    from repro.distributed.sharding import serve_rules
    from repro.launch.mesh import make_local_mesh
    from repro.models.api import build_model
    from repro.pipeline.cascade import LMCascade, make_escalate
    from repro.serving.engine import LMServer

    s = scenario
    mesh = make_local_mesh()
    rules = serve_rules(multi_pod=False)
    cfg = reduced_config(ARCHITECTURES["smollm-360m"], num_layers=2,
                         d_model=64)
    model = build_model(cfg, mesh, rules)
    params = model.init(jax.random.PRNGKey(s.seed))

    def service_model(scale: float):
        def sm(kind: str, batch: int, tokens: int) -> float:
            if kind == "prefill":
                return scale * (s.base_latency
                                + s.per_item_latency * batch * tokens)
            return scale * (s.base_latency / 4 + s.per_item_latency * batch)
        return sm

    clock = VirtualClock()
    # one tracer spans both tiers: a draft request and its escalated verify
    # re-decode appear as two traces on one shared timeline
    draft = LMServer(model, mesh, rules, slots=s.slots, max_len=64,
                     slo=s.slo, temperature=0.0, seed=s.seed, clock=clock,
                     service_model=service_model(1.0), model_id="draft",
                     metrics=MetricsRegistry(s.slo),
                     admission_control=draft_admission, tracer=tracer,
                     audit=audit)
    verify = LMServer(model, mesh, rules, slots=s.slots, max_len=64,
                      slo=s.slo, temperature=0.0, seed=s.seed + 1,
                      clock=clock, service_model=service_model(4.0),
                      model_id="verify", metrics=MetricsRegistry(s.slo),
                      admission_control=verify_admission, tracer=tracer,
                      audit=audit)
    casc = LMCascade(draft, verify, escalate=make_escalate(threshold),
                     slo=s.slo)
    if sampler is not None:
        # burn-rate monitoring tracks the draft tier (every request enters
        # there); both tiers' fleet series are sampled
        sampler.bind(metrics=draft.metrics, tracer=tracer)
        sampler.add_probe(draft.timeseries_probe)
        sampler.add_probe(verify.timeseries_probe)
    rng = np.random.default_rng(s.seed)
    times = s.arrival_times()[:s.lm_requests]
    if len(times) == 0:
        times = np.asarray([0.0])
    pending = [(float(t), rng.integers(0, cfg.vocab_size, size=s.prompt_len))
               for t in times]
    i = 0
    while i < len(pending) or casc.pending:
        while i < len(pending) and pending[i][0] <= clock.now:
            at, prompt = pending[i]
            casc.submit(prompt, max_new_tokens=s.max_new_tokens, now=at)
            i += 1
        if not casc.pending and i < len(pending):
            clock.advance(pending[i][0] - clock.now)
            if sampler is not None:
                sampler.sample_until(clock.now)
            continue
        casc.step(params, params)
        if sampler is not None:
            sampler.sample_until(clock.now)
    rep = casc.report()
    rep["scenario"] = dataclasses.asdict(s)
    rep["meta"] = trace_meta(s)
    return rep


def pipeline_scenario(name: str = "pipeline", **overrides: Any) -> Scenario:
    """Look up a named workload scenario (default: the pipeline regime
    registered in ``workloads.scenario.SCENARIOS``) with overrides."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; have {sorted(SCENARIOS)}")
    return dataclasses.replace(SCENARIOS[name], **overrides)
