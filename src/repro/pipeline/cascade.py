"""LM cascade serving: draft-then-verify between two LMServer engines
(DESIGN.md §12).

The cascade analogue of the frontend pipeline for the continuous-batching
stack: every prompt decodes on a cheap *draft* engine; the engine's
``on_finish`` hook hands the finished request to the cascade, which either
accepts the draft answer or escalates the prompt to an expensive *verify*
engine. End-to-end latency and SLO attainment are accounted once per
request in the cascade's own ``repro.metrics/v1`` registry; each engine
keeps its private registry so per-engine service stats stay separable.

The escalation predicate is pluggable. The default is a deterministic
output-quality proxy — the distinct-token ratio of the draft generation
(degenerate repetition reads as low confidence) — chosen because it is a
pure function of the tokens, so calibrated-simulation runs stay
byte-identical. Production deployments would plug in a logprob margin.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

import numpy as np

from repro.core import metrics as M
from repro.core.metrics import MetricsRegistry
from repro.serving.engine import LMServer, Request

# escalate(request) -> True to re-run the prompt on the verify engine
EscalateFn = Callable[[Request], bool]


def distinct_token_confidence(tokens: Sequence[int]) -> float:
    """Distinct-token ratio of a generation — 1.0 for all-unique output,
    approaching 0 for degenerate repetition."""
    if not tokens:
        return 0.0
    return len(set(int(t) for t in tokens)) / len(tokens)


def make_escalate(threshold: float) -> EscalateFn:
    """Escalate drafts whose distinct-token confidence is below
    ``threshold`` (0.0 never escalates; anything > 1.0 always does)."""

    def escalate(r: Request) -> bool:
        return distinct_token_confidence(r.tokens) < threshold

    return escalate


class LMCascade:
    """Two-engine cascade over a shared (virtual or wall) clock.

    ``draft`` and ``verify`` must share the same clock; give each its own
    ``MetricsRegistry`` — the cascade owns the end-to-end registry."""

    def __init__(self, draft: LMServer, verify: LMServer, *,
                 escalate: Optional[EscalateFn] = None,
                 slo: float = 0.5,
                 metrics: Optional[MetricsRegistry] = None):
        if draft.clock is not verify.clock:
            raise ValueError("draft and verify engines must share one clock")
        if draft.metrics is verify.metrics:
            raise ValueError(
                "give each engine its own registry; the cascade accounts "
                "end-to-end metrics itself")
        self.draft = draft
        self.verify = verify
        self.escalate = escalate if escalate is not None else make_escalate(0.9)
        self.slo = slo
        self.metrics = metrics if metrics is not None else MetricsRegistry(slo)
        self.results: Dict[int, Dict[str, Any]] = {}
        self.shed_cids: set = set()
        self.escalated = 0
        self._next_id = 0
        self._draft_rid_to_cid: Dict[int, int] = {}
        self._verify_rid_to_cid: Dict[int, int] = {}
        self._meta: Dict[int, Dict[str, Any]] = {}   # cid -> bookkeeping
        draft.on_finish = self._on_draft_finish
        verify.on_finish = self._on_verify_finish

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               now: Optional[float] = None) -> int:
        """Enqueue a prompt on the draft tier; returns the cascade id."""
        cid = self._next_id
        self._next_id += 1
        at = self.draft.clock() if now is None else now
        self.metrics.inc(M.QUERIES_SUBMITTED)
        self.metrics.mark(at)
        self._meta[cid] = {"prompt": np.asarray(prompt, np.int32),
                           "max_new_tokens": max_new_tokens, "arrival": at}
        shed0 = self.draft.shed
        rid = self.draft.submit(prompt, max_new_tokens=max_new_tokens,
                                now=at)
        if self.draft.shed > shed0:
            # the draft engine's admission control shed it: such a request
            # never fires on_finish, so account the cascade-level shed here
            del self._meta[cid]
            self.shed_cids.add(cid)
            self.metrics.inc(M.QUERIES_SHED)
            return cid
        self._draft_rid_to_cid[rid] = cid
        return cid

    def _on_draft_finish(self, r: Request) -> None:
        cid = self._draft_rid_to_cid.pop(r.request_id, None)
        if cid is None:
            return
        meta = self._meta[cid]
        # an injected draft failure (repro.faults.RequestFaults) forces
        # escalation: the draft answer is unusable, so the verify tier is
        # the retry path (counted under faults.retries)
        failed = bool(getattr(r, "failed", False))
        if failed or self.escalate(r):
            self.escalated += 1
            self.metrics.inc(M.PIPELINE_ESCALATIONS)
            if failed:
                self.metrics.inc_both(M.FAULTS_RETRIES,
                                      model=self.draft.model_id)
            now = self.draft.clock()
            shed0 = self.verify.shed
            rid = self.verify.submit(meta["prompt"],
                                     max_new_tokens=meta["max_new_tokens"],
                                     now=now)
            if self.verify.shed > shed0:
                # verify tier refused: degrade to the draft answer instead
                # of losing the request (shed requests never fire on_finish)
                self.metrics.inc(M.QUERIES_DEGRADED)
                self._complete(cid, r, tier="draft")
                return
            self._verify_rid_to_cid[rid] = cid
            # keep the draft answer: if the verify pass itself fails we
            # degrade to it rather than losing the request
            meta["draft"] = r
            return
        self.metrics.inc(M.PIPELINE_STAGES_SKIPPED)
        self._complete(cid, r, tier="draft")

    def _on_verify_finish(self, r: Request) -> None:
        cid = self._verify_rid_to_cid.pop(r.request_id, None)
        if cid is None:
            return
        draft = self._meta[cid].get("draft")
        if getattr(r, "failed", False) and draft is not None \
                and not getattr(draft, "failed", False):
            # graceful degradation (DESIGN.md §14): a failed verify pass
            # falls back to the draft answer it was double-checking
            self.metrics.inc(M.QUERIES_DEGRADED)
            self._complete(cid, draft, tier="draft", finish=r.finish_time)
            return
        self._complete(cid, r, tier="verify")

    def _complete(self, cid: int, r: Request, *, tier: str,
                  finish: Optional[float] = None) -> None:
        meta = self._meta.pop(cid)
        if finish is None:
            finish = (r.finish_time if r.finish_time is not None
                      else self.draft.clock())
        latency = finish - meta["arrival"]
        self.metrics.inc(M.QUERIES_COMPLETED)
        self.metrics.observe_latency(latency)
        self.metrics.mark(finish)
        self.results[cid] = {"tokens": list(r.tokens), "tier": tier,
                             "latency": latency}

    # ------------------------------------------------------------------
    @property
    def pending(self) -> bool:
        return self.draft.pending or self.verify.pending

    def step(self, draft_params, verify_params) -> None:
        """Advance both tiers one engine step each (draft first — its
        completions may enqueue verify work that the verify step can then
        admit in the same cascade step)."""
        if self.draft.pending:
            self.draft.step(draft_params)
        if self.verify.pending:
            self.verify.step(verify_params)

    def run(self, draft_params, verify_params, *,
            max_steps: int = 100_000) -> None:
        steps = 0
        while self.pending and steps < max_steps:
            self.step(draft_params, verify_params)
            steps += 1

    # ------------------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        """End-to-end report plus a ``cascade`` section with per-tier engine
        stats (each tier's private registry, rendered with the same schema)."""
        rep = self.metrics.report("lmcascade")
        completed = self.metrics.counter(M.QUERIES_COMPLETED)
        rep["cascade"] = {
            "escalated": self.escalated,
            "escalation_rate": (self.escalated / completed) if completed
                               else 0.0,
            "draft": self.draft.report(),
            "verify": self.verify.report(),
        }
        return rep

    def report_json(self, **extra: Any) -> str:
        import json
        rep = self.report()
        rep.update(extra)
        return json.dumps(rep, sort_keys=True, indent=2)
