"""Pipeline graph spec (DESIGN.md §12).

A ``PipelineGraph`` composes model containers into a DAG of *stages*. Each
stage evaluates zero or more models on one prepared input (fan-out *within*
a stage is an ensemble evaluated in parallel) and reduces the results with a
``combine`` function; edges between stages carry combined outputs (fan-in).
A stage with an optional ``gate`` predicate runs conditionally on its
parents' outputs — the cascade pattern, where a cheap draft stage answers
and only low-confidence queries escalate to an accurate verify stage
(confidence = ``agreement_confidence`` over the draft ensemble, reused from
``core/straggler.py``).

The spec is pure data + pure functions; execution (queues, deadlines,
caching, straggler mitigation) lives in ``pipeline/executor.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.straggler import agreement_confidence, assemble_preds

# combine(stage_input, preds, parent_outputs) -> stage output
CombineFn = Callable[[Any, Dict[str, Any], Dict[str, Any]], Any]
# prepare(query_x, parent_outputs) -> model input for this stage
PrepareFn = Callable[[Any, Dict[str, Any]], Any]
# gate(parent_outputs) -> True to run the stage, False to skip it
GateFn = Callable[[Dict[str, Any]], bool]


@dataclass(frozen=True)
class Stage:
    """One node of the pipeline DAG.

    ``model_ids`` fan out within the stage (evaluated in parallel, combined
    by ``combine``); an empty tuple makes a pure fan-in/combine node that
    costs nothing and never touches a queue. ``prepare`` builds the model
    input from the query and the parents' outputs; the default passes the
    sole parent's output through when it is an ndarray (feature-transform
    chains) and falls back to the raw query input otherwise (a cascade's
    verify stage re-reads the features, not the draft's structured output).
    """

    name: str
    model_ids: Tuple[str, ...] = ()
    parents: Tuple[str, ...] = ()
    combine: Optional[CombineFn] = None
    prepare: Optional[PrepareFn] = None
    gate: Optional[GateFn] = None

    def prepare_input(self, x: Any, parent_outputs: Dict[str, Any]) -> Any:
        if self.prepare is not None:
            return self.prepare(x, parent_outputs)
        arrays = [v for v in (parent_outputs[p] for p in self.parents)
                  if isinstance(v, np.ndarray)]
        return arrays[0] if len(arrays) == 1 else x

    def combine_preds(self, xin: Any, preds: Dict[str, Any],
                      parent_outputs: Dict[str, Any]) -> Any:
        if self.combine is not None:
            return self.combine(xin, preds, parent_outputs)
        if len(preds) == 1:
            return next(iter(preds.values()))
        vals = [np.asarray(preds[m], np.float32)
                for m in self.model_ids if m in preds]
        return np.mean(vals, axis=0)


class PipelineGraph:
    """Validated DAG of stages with exactly one output stage."""

    def __init__(self, stages: Sequence[Stage], output: Optional[str] = None):
        self.stages: Dict[str, Stage] = {}
        for s in stages:
            if s.name in self.stages:
                raise ValueError(f"duplicate stage name {s.name!r}")
            self.stages[s.name] = s
        for s in self.stages.values():
            for p in s.parents:
                if p not in self.stages:
                    raise ValueError(
                        f"stage {s.name!r} has unknown parent {p!r}")
        self.order = self._topo_order()
        leaves = [n for n in self.stages
                  if not any(n in c.parents for c in self.stages.values())]
        if output is None:
            if len(leaves) != 1:
                raise ValueError(
                    f"graph needs exactly one output stage, found {leaves}")
            output = leaves[0]
        elif output not in self.stages:
            raise ValueError(f"unknown output stage {output!r}")
        self.output = output

    def _topo_order(self) -> List[str]:
        seen: Dict[str, int] = {}       # 0 = visiting, 1 = done

        order: List[str] = []

        def visit(n: str) -> None:
            state = seen.get(n)
            if state == 1:
                return
            if state == 0:
                raise ValueError(f"cycle through stage {n!r}")
            seen[n] = 0
            for p in self.stages[n].parents:
                visit(p)
            seen[n] = 1
            order.append(n)

        for n in sorted(self.stages):
            visit(n)
        return order

    def roots(self) -> List[Stage]:
        return [s for s in (self.stages[n] for n in self.order)
                if not s.parents]

    def children(self, name: str) -> List[Stage]:
        return [self.stages[n] for n in self.order
                if name in self.stages[n].parents]

    def model_ids(self) -> List[str]:
        out: List[str] = []
        for n in self.order:
            for mid in self.stages[n].model_ids:
                if mid not in out:
                    out.append(mid)
        return out

    def describe(self) -> Dict[str, Any]:
        """Report-stable summary (sorted keys, plain types)."""
        return {
            "output": self.output,
            "stages": [{
                "name": n,
                "models": list(self.stages[n].model_ids),
                "parents": list(self.stages[n].parents),
                "gated": self.stages[n].gate is not None,
            } for n in self.order],
        }


# ---------------------------------------------------------------------------
# canonical graph shapes
# ---------------------------------------------------------------------------

def agreement_combine(model_ids: Sequence[str]) -> CombineFn:
    """Ensemble combine that also measures itself: mean prediction plus the
    fraction of arrived models agreeing with the plurality vote
    (``agreement_confidence``, core/straggler.py) — the signal a cascade
    gate consumes."""
    ids = tuple(model_ids)

    def combine(xin, preds, parent_outputs):
        mat, avail = assemble_preds(ids, preds)
        conf = agreement_confidence(mat, avail)
        y = np.asarray(mat)[np.asarray(avail)].mean(axis=0)
        return {"y": y, "confidence": conf}

    return combine


def cascade_graph(draft_models: Sequence[str], verify_model: str, *,
                  preprocess_model: Optional[str] = None,
                  threshold: float = 0.75) -> PipelineGraph:
    """Two-tier cascade: a cheap draft ensemble answers every query; only
    queries whose draft agreement confidence falls below ``threshold``
    escalate to the accurate verify model.

    Shape: [prep ->] draft(ensemble) -> verify(gated) -> output(combine).
    The verify stage re-reads the (preprocessed) features via fan-in from
    the prep stage; the output stage prefers the verify answer when it ran
    and degrades to the draft answer when verify was skipped *or* shed."""
    draft_ids = tuple(draft_models)
    stages: List[Stage] = []
    feature_stage = ()
    if preprocess_model is not None:
        stages.append(Stage("prep", (preprocess_model,)))
        feature_stage = ("prep",)

    stages.append(Stage("draft", draft_ids, parents=feature_stage,
                        combine=agreement_combine(draft_ids)))

    def features(x, outs):
        # raw query input when there is no prep stage — or when prep was
        # shed outright (its output is None)
        p = outs.get("prep")
        return p if p is not None else x

    def gate(outs):
        d = outs["draft"]
        return d is None or d["confidence"] < threshold

    stages.append(Stage("verify", (verify_model,),
                        parents=feature_stage + ("draft",),
                        prepare=features, gate=gate))

    def output_combine(xin, preds, outs):
        v, d = outs.get("verify"), outs.get("draft")
        if v is not None:
            return {"y": np.asarray(v, np.float32), "confidence": 1.0,
                    "escalated": True}
        if d is None:
            return None                 # both tiers shed: no answer
        return {"y": d["y"], "confidence": d["confidence"],
                "escalated": False}

    stages.append(Stage("output", parents=("draft", "verify"),
                        combine=output_combine))
    return PipelineGraph(stages)


def fanout_graph(branch_models: Sequence[str], *,
                 preprocess_model: Optional[str] = None) -> PipelineGraph:
    """Fan-out/fan-in: [prep ->] one stage per branch model, all combined by
    agreement-weighted mean — the 'preprocess -> {fast, accurate} ->
    combine' shape from the paper's model-composition pitch."""
    branch_ids = tuple(branch_models)
    stages: List[Stage] = []
    feature_stage = ()
    if preprocess_model is not None:
        stages.append(Stage("prep", (preprocess_model,)))
        feature_stage = ("prep",)
    for mid in branch_ids:
        stages.append(Stage(f"branch_{mid}", (mid,), parents=feature_stage))

    def output_combine(xin, preds, outs):
        got = {m: outs[f"branch_{m}"] for m in branch_ids
               if outs.get(f"branch_{m}") is not None}
        if not got:
            return None
        mat, avail = assemble_preds(tuple(got), got)
        return {"y": np.asarray(mat).mean(axis=0),
                "confidence": agreement_confidence(mat, avail),
                "escalated": False}

    stages.append(Stage("output",
                        parents=tuple(f"branch_{m}" for m in branch_ids),
                        combine=output_combine))
    return PipelineGraph(stages)
