"""CLI: serve a workload trace through a prediction pipeline and print the
structured report.

    PYTHONPATH=src python -m repro.pipeline.run --scenario cascade
    PYTHONPATH=src python -m repro.pipeline.run --scenario fanout --seed 7
    PYTHONPATH=src python -m repro.pipeline.run --scenario lmcascade \
        --report-out report.json

``--scenario`` picks the pipeline shape (DESIGN.md §12): ``cascade`` and
``fanout`` run DAGs of model containers on the Clipper frontend;
``lmcascade`` runs draft-then-verify across two LM engines. ``--profile``
picks the workload trace (a named scenario from DESIGN.md §9; default the
``pipeline`` regime). Reports use the shared ``repro.metrics/v1`` schema
plus a ``pipeline`` / ``cascade`` section, and are byte-identical per seed.
"""

from __future__ import annotations

import argparse
import sys

from repro.obs.cli import add_fleet_args, build_fleet, write_fleet
from repro.pipeline.scenario import (CASCADE_THRESHOLD, pipeline_scenario,
                                     run_lmcascade, run_pipeline)
from repro.workloads.scenario import SCENARIOS

PIPELINES = ("cascade", "fanout", "lmcascade")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.pipeline.run",
        description="Serve a workload trace through a prediction pipeline "
                    "(DAG composition / cascade) and emit a telemetry "
                    "report.")
    p.add_argument("--scenario", default="cascade", choices=PIPELINES,
                   help="pipeline shape (see DESIGN.md §12)")
    p.add_argument("--profile", default="pipeline", choices=sorted(SCENARIOS),
                   help="named workload profile supplying the arrival trace")
    p.add_argument("--seed", type=int, default=None,
                   help="override the profile seed")
    p.add_argument("--duration", type=float, default=None,
                   help="override the trace duration (s)")
    p.add_argument("--rate", type=float, default=None,
                   help="override the mean arrival rate (qps)")
    p.add_argument("--pool", type=int, default=None,
                   help="unique-query pool size (0 = all unique)")
    p.add_argument("--threshold", type=float, default=None,
                   help="cascade escalation threshold (frontend cascade: "
                        f"draft agreement, default {CASCADE_THRESHOLD}; "
                        "lmcascade: distinct-token confidence, default 0.9)")
    p.add_argument("--no-cache", dest="use_cache", action="store_false",
                   help="disable the intermediate-result cache "
                        "(cascade/fanout only)")
    p.add_argument("--report-out", default=None,
                   help="write the JSON report here instead of stdout")
    p.add_argument("--trace-out", default=None,
                   help="record per-query spans (repro.obs) and write the "
                        "repro.trace/v1 span log here — byte-identical per "
                        "seed; convert with python -m repro.obs.export")
    p.add_argument("--trace-sample-rate", type=float, default=1.0,
                   help="head-based trace sampling rate in [0, 1] "
                        "(default 1.0; only meaningful with --trace-out)")
    add_fleet_args(p)
    return p


def main(argv=None) -> int:
    import json

    parser = build_parser()
    args = parser.parse_args(argv)
    overrides = {k: v for k, v in (("seed", args.seed),
                                   ("duration", args.duration),
                                   ("rate", args.rate),
                                   ("pool", args.pool))
                 if v is not None}
    sc = pipeline_scenario(args.profile, **overrides)
    if sc.duration <= 0:
        parser.error("--duration must be > 0")
    if sc.rate <= 0:
        parser.error("--rate must be > 0")
    if sc.kind != "poisson" and sc.rate > sc.peak_rate:
        parser.error(f"--rate {sc.rate:g} exceeds the {sc.name!r} profile's "
                     f"peak rate {sc.peak_rate:g}")
    if sc.pool < 0:
        parser.error("--pool must be >= 0")
    tracer = None
    if args.trace_out:
        if not 0.0 <= args.trace_sample_rate <= 1.0:
            parser.error("--trace-sample-rate must be in [0, 1]")
        from repro.obs import Tracer
        tracer = Tracer(sample_rate=args.trace_sample_rate, seed=sc.seed)
    sampler, audit = build_fleet(args, parser)
    if args.scenario == "lmcascade":
        if not args.use_cache:
            parser.error("--no-cache applies to the frontend pipelines "
                         "only (lmcascade has no intermediate-result cache)")
        thr = 0.9 if args.threshold is None else args.threshold
        rep = run_lmcascade(sc, threshold=thr, tracer=tracer,
                            sampler=sampler, audit=audit)
    else:
        thr = CASCADE_THRESHOLD if args.threshold is None else args.threshold
        rep = run_pipeline(sc, args.scenario, threshold=thr,
                           use_cache=args.use_cache, tracer=tracer,
                           sampler=sampler, audit=audit)
    text = json.dumps(rep, sort_keys=True, indent=2)
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            f.write(tracer.to_json() + "\n")
    write_fleet(args, sampler, audit)
    if args.report_out:
        with open(args.report_out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
