"""Clipper core: the paper's contribution as composable JAX modules.

Layers (paper Figure 1):
  model selection  - selection.py (Exp3/Exp4), context.py, straggler.py
  model abstraction - cache.py (CLOCK), batching.py (AIMD), containers.py
  frontend          - frontend.py (REST-equivalent: submit / feedback)
"""

from repro.core.batching import (AIMDController, BatchQueue, FixedController,
                                 QuantileRegressionController, bucket)
from repro.core.cache import ClockCache, PredictionCache
from repro.core.containers import (JaxModelContainer, ReplicaSet,
                                   linear_latency)
from repro.core.context import ContextualStore
from repro.core.frontend import Clipper, make_clipper
from repro.core.interfaces import Feedback, Prediction, Query
from repro.core.metrics import MetricsRegistry, StreamingHistogram, VirtualClock
from repro.core.selection import (Exp3Policy, Exp4Policy, exp3_init,
                                  exp3_observe, exp3_probs, exp4_combine,
                                  exp4_init, exp4_observe, exp4_weights)
from repro.core.straggler import DeadlineTracker, assemble_preds

__all__ = [
    "AIMDController", "BatchQueue", "FixedController",
    "QuantileRegressionController", "bucket", "ClockCache", "PredictionCache",
    "JaxModelContainer", "ReplicaSet", "linear_latency", "ContextualStore",
    "Clipper", "make_clipper", "Feedback", "Prediction", "Query",
    "Exp3Policy", "Exp4Policy", "exp3_init", "exp3_observe", "exp3_probs",
    "exp4_combine", "exp4_init", "exp4_observe", "exp4_weights",
    "DeadlineTracker", "assemble_preds",
    "MetricsRegistry", "StreamingHistogram", "VirtualClock",
]
