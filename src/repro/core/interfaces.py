"""Clipper's narrow-waist interfaces (paper §3, Listings 1 & 2).

``pred_batch`` is the uniform batch prediction interface every model
container implements; ``SelectionPolicy`` is the select/combine/observe API
that all model-selection techniques are expressed in."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np


@dataclass
class Query:
    query_id: int
    x: Any                                  # model input (np array / token ids)
    context_id: int = 0                     # user / session (paper §5.3)
    arrival_time: float = 0.0
    deadline: Optional[float] = None        # absolute; set from the SLO


@dataclass
class Prediction:
    query_id: int
    y: Any
    confidence: float = 1.0
    model_ids: Tuple[str, ...] = ()
    latency: float = 0.0
    from_cache: bool = False
    missing_models: Tuple[str, ...] = ()    # straggler-dropped (paper §5.2.2)


@dataclass
class Feedback:
    query_id: int
    x: Any
    y_true: Any
    context_id: int = 0


@runtime_checkable
class ModelContainer(Protocol):
    """Paper Listing 1: the common batch prediction interface."""

    model_id: str

    def pred_batch(self, inputs: Sequence[Any]) -> List[Any]:
        """Evaluate a batch; returns one output per input."""
        ...


class SelectionPolicy(Protocol):
    """Paper Listing 2: init / select / combine / observe."""

    def init(self) -> Any:
        ...

    def select(self, s: Any, x: Any, rng: np.random.Generator) -> List[str]:
        ...

    def combine(self, s: Any, x: Any, preds: Dict[str, Any]
                ) -> Tuple[Any, float]:
        ...

    def observe(self, s: Any, x: Any, y_true: Any,
                preds: Dict[str, Any]) -> Any:
        ...


Clock = Callable[[], float]


def monotonic_clock() -> float:
    return time.monotonic()
