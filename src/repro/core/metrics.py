"""Unified telemetry for both serving stacks (paper §6 methodology).

Clipper's evaluation is entirely measured behaviour — tail latency,
throughput, and SLO attainment under controlled arrival processes (Figs 4,
6, 9). This module is the single metrics layer both stacks report through:

* ``StreamingHistogram`` — fixed-layout log-bucketed histogram with
  deterministic percentile interpolation. Bounded memory, order-insensitive,
  and bit-reproducible: the same observations always produce the same
  P50/P95/P99, which turns tail latency into an *exact* test oracle.
* ``MetricsRegistry`` — counters, gauges, and histograms keyed by name plus
  an optional ``model`` label, with a canonical ``report()`` schema
  (``repro.metrics/v1``) shared by the discrete-event ``Clipper`` frontend
  and the continuous-batching ``LMServer``.
* ``VirtualClock`` — an advanceable clock satisfying the ``Clock`` protocol;
  with it, calibrated-simulation runs (DESIGN.md §8) produce byte-identical
  reports from a seed.

The registry is clock-agnostic: it never reads time itself. Callers pass
event times via ``mark()`` and durations via ``observe()``; throughput is
derived from the marked span.
"""

from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional, Tuple

SCHEMA = "repro.metrics/v1"

# Canonical metric names — both stacks use exactly these.
QUERIES_SUBMITTED = "queries.submitted"
QUERIES_COMPLETED = "queries.completed"
QUERIES_SHED = "queries.shed"          # admission-rejected before enqueue
QUERIES_DEGRADED = "queries.degraded"  # served with a reduced ensemble
QUERIES_ROUTED = "queries.routed"      # enqueued to a model's replica set
REPLICAS_ADDED = "cluster.replicas_added"
REPLICAS_RETIRED = "cluster.replicas_retired"
SLO_VIOLATIONS = "slo.violations"
CACHE_HITS = "cache.hits"
CACHE_MISSES = "cache.misses"
STRAGGLER_PARTIAL = "straggler.partial_queries"
STRAGGLER_DROPPED = "straggler.dropped_models"
PIPELINE_STAGE_JOBS = "pipeline.stage_jobs"      # stage jobs launched
PIPELINE_STAGES_SKIPPED = "pipeline.stages_skipped"  # gated off (cascade)
PIPELINE_ESCALATIONS = "pipeline.escalations"    # gated stages that ran
PIPELINE_STAGES_SHED = "pipeline.stages_shed"    # stage jobs admission shed
PIPELINE_STAGES_DEGRADED = "pipeline.stages_degraded"  # stage jobs narrowed
PIPELINE_STAGES_FAILED = "pipeline.stages_failed"  # every model of a stage lost
# fault injection + recovery (DESIGN.md §14)
FAULTS_CRASHES = "faults.crashes"            # batches lost to crashed replicas
FAULTS_TRANSIENT = "faults.transient_errors"  # fail-fast batch errors
FAULTS_SLOW = "faults.slow_batches"          # batches under degraded latency
MODEL_FAILURES = "faults.failures"           # per-container failure total
FAULTS_DETECTED = "faults.detected"          # detector marked replica down
FAULTS_RECOVERED = "faults.recovered"        # probed replica rejoined routing
FAULTS_REQUEUED = "faults.requeued_queries"  # drained off a dead replica
FAULTS_RETRIES = "faults.retries"            # per-query re-dispatches
FAULTS_RETRY_EXHAUSTED = "faults.retry_exhausted"  # budget spent, gave up
FAULTS_HEDGES = "faults.hedges"              # hedged duplicate dispatches
FAULTS_HEDGE_WINS = "faults.hedge_wins"      # hedge finished before primary
BATCHES = "batches.dispatched"
LATENCY = "latency_s"          # end-to-end query latency histogram
SERVICE = "service_s"          # per-batch model service time histogram
BATCH_SIZE = "batch.size"      # dispatched batch-size histogram
QUEUE_DEPTH = "queue.depth"    # queue depth sampled at dispatch


class StreamingHistogram:
    """Log-bucketed streaming histogram with deterministic percentiles.

    Layout: ``buckets_per_decade`` geometric buckets per decade spanning
    [lo, hi); one underflow and one overflow bucket. An observation ``v``
    lands in bucket ``floor(log(v / lo) / log(g))`` for growth factor
    ``g = 10 ** (1 / buckets_per_decade)``. ``percentile(p)`` walks the
    cumulative counts to the bucket containing rank ``ceil(p/100 * n)`` and
    returns that bucket's geometric midpoint — a pure function of the
    observation multiset, exact for test oracles. True ``min``/``max``/
    ``sum`` are tracked exactly alongside.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 buckets_per_decade: int = 24):
        assert 0 < lo < hi and buckets_per_decade > 0
        self.lo = lo
        self.hi = hi
        self.bpd = buckets_per_decade
        self._log_g = math.log(10.0) / buckets_per_decade
        self.nbuckets = int(math.ceil(
            math.log(hi / lo) / self._log_g)) + 2      # + under/overflow
        self._counts = [0] * self.nbuckets
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _bucket(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return self.nbuckets - 1
        return 1 + int(math.log(v / self.lo) / self._log_g)

    def _midpoint(self, b: int) -> float:
        if b <= 0:
            return self.lo
        if b >= self.nbuckets - 1:
            return self.hi
        return self.lo * math.exp((b - 0.5) * self._log_g)

    def observe(self, v: float) -> None:
        v = float(v)
        self._counts[self._bucket(v)] += 1
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def percentile(self, p: float) -> float:
        """Geometric midpoint of the bucket holding rank ceil(p/100 * n)."""
        if self.count == 0:
            return float("nan")
        rank = max(1, math.ceil(p / 100.0 * self.count))
        cum = 0
        for b, c in enumerate(self._counts):
            cum += c
            if cum >= rank:
                return self._midpoint(b)
        return self._midpoint(self.nbuckets - 1)    # pragma: no cover

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def summary(self) -> Dict[str, Any]:
        # schema-stable: the key set never depends on whether anything was
        # observed (empty stats are null, valid JSON), so report consumers
        # can index unconditionally
        if self.count == 0:
            return {"count": 0, "sum": None, "mean": None, "min": None,
                    "max": None, "p50": None, "p95": None, "p99": None}
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class VirtualClock:
    """Advanceable clock for calibrated simulation: satisfies the ``Clock``
    protocol (zero-arg callable returning seconds) and is stepped explicitly
    by whatever owns the timeline."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        assert dt >= 0.0
        self.now += dt
        return self.now


_Key = Tuple[str, Optional[str]]           # (name, model label)


class MetricsRegistry:
    """Counters + gauges + histograms with per-model labels and the shared
    ``repro.metrics/v1`` report schema."""

    # histogram layouts by metric name: (lo, hi, buckets_per_decade)
    _LAYOUTS = {
        LATENCY: (1e-6, 1e4, 24),
        SERVICE: (1e-6, 1e4, 24),
        BATCH_SIZE: (1.0, 2.0 ** 13, 24),
        QUEUE_DEPTH: (1.0, 2.0 ** 13, 24),
    }

    def __init__(self, slo: Optional[float] = None):
        self.slo = slo
        self._counters: Dict[_Key, int] = {}
        self._hists: Dict[_Key, StreamingHistogram] = {}
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    # -- recording ------------------------------------------------------
    def inc(self, name: str, n: int = 1, *, model: Optional[str] = None):
        key = (name, model)
        self._counters[key] = self._counters.get(key, 0) + n

    def observe(self, name: str, value: float, *,
                model: Optional[str] = None) -> None:
        key = (name, model)
        h = self._hists.get(key)
        if h is None:
            lo, hi, bpd = self._LAYOUTS.get(name, (1e-6, 1e4, 24))
            h = self._hists[key] = StreamingHistogram(lo, hi, bpd)
        h.observe(value)

    def inc_both(self, name: str, n: int = 1, *, model: str) -> None:
        """Increment the global series and the model-labeled series together
        — the paired emission every dispatch site needs."""
        self.inc(name, n)
        self.inc(name, n, model=model)

    def observe_both(self, name: str, value: float, *, model: str) -> None:
        """Observe into the global histogram and the model-labeled one."""
        self.observe(name, value)
        self.observe(name, value, model=model)

    def observe_latency(self, latency: float, *,
                        model: Optional[str] = None) -> None:
        """End-to-end latency + SLO attainment in one call.

        Deadline-finalized queries land *exactly* on the SLO (straggler
        mitigation, paper §5.2.2) — the epsilon keeps float noise in
        ``arrival + slo - arrival`` from miscounting them as violations.

        With a ``model`` label the observation lands in *both* the global
        and the labeled latency histogram (like the violation counters), so
        per-model tagging never starves the cross-stack global series."""
        self.observe(LATENCY, latency)
        if model is not None:
            self.observe(LATENCY, latency, model=model)
        if self.slo is not None and latency - self.slo > 1e-12:
            self.inc(SLO_VIOLATIONS)
            if model is not None:
                self.inc(SLO_VIOLATIONS, model=model)

    def mark(self, now: float) -> None:
        """Record an event time; the marked span defines the run duration."""
        if self._t_first is None:
            self._t_first = now
        self._t_last = now if self._t_last is None else max(self._t_last, now)

    # -- reading --------------------------------------------------------
    def counter(self, name: str, *, model: Optional[str] = None) -> int:
        return self._counters.get((name, model), 0)

    def hist(self, name: str, *,
             model: Optional[str] = None) -> Optional[StreamingHistogram]:
        return self._hists.get((name, model))

    def percentile(self, name: str, p: float, *,
                   model: Optional[str] = None) -> float:
        h = self.hist(name, model=model)
        return h.percentile(p) if h is not None else float("nan")

    @property
    def duration(self) -> float:
        if self._t_first is None or self._t_last is None:
            return 0.0
        return self._t_last - self._t_first

    def _models(self) -> List[str]:
        out = {m for (_, m) in self._counters if m is not None}
        out |= {m for (_, m) in self._hists if m is not None}
        return sorted(out)

    def _hist_summary(self, name: str, model: Optional[str] = None):
        h = self.hist(name, model=model)
        return (h if h is not None else StreamingHistogram()).summary()

    def report(self, stack: str) -> Dict[str, Any]:
        """The canonical cross-stack report (``repro.metrics/v1``)."""
        completed = self.counter(QUERIES_COMPLETED)
        submitted = self.counter(QUERIES_SUBMITTED)
        violations = self.counter(SLO_VIOLATIONS)
        shed = self.counter(QUERIES_SHED)
        hits, misses = self.counter(CACHE_HITS), self.counter(CACHE_MISSES)
        dur = self.duration
        rep = {
            "schema": SCHEMA,
            "stack": stack,
            "duration_s": dur,
            "queries": {
                "submitted": submitted,
                "completed": completed,
            },
            # a degenerate marked span (no marks, or a single event) has no
            # rate to derive — emit null rather than a misleading 0.0 qps,
            # so report consumers can tell "no throughput signal" from
            # "measured zero" (validated by repro.metrics.validate)
            "throughput_qps": (completed / dur) if dur > 0 else None,
            "latency_s": self._hist_summary(LATENCY),
            "slo": {
                "target_s": self.slo,
                "violations": violations,
                "rate": (violations / completed if completed else 0.0),
                # fraction of *submitted* queries answered within the SLO —
                # shed queries count against attainment, so admission control
                # can't game the metric by rejecting everything
                "attainment": ((completed - violations) / submitted
                               if submitted else 1.0),
            },
            "admission": {
                "shed": shed,
                "degraded": self.counter(QUERIES_DEGRADED),
                "shed_rate": shed / submitted if submitted else 0.0,
            },
            "cache": {
                "hits": hits,
                "misses": misses,
                "hit_rate": hits / (hits + misses) if (hits + misses) else 0.0,
            },
            "batch_size": self._hist_summary(BATCH_SIZE),
            "queue_depth": self._hist_summary(QUEUE_DEPTH),
            "stragglers": {
                "partial_queries": self.counter(STRAGGLER_PARTIAL),
                "dropped_models": self.counter(STRAGGLER_DROPPED),
            },
            # always present (all-zero when no fault plan is attached) so
            # the report key set is schema-stable across healthy and
            # faulted runs
            "faults": {
                "crashes": self.counter(FAULTS_CRASHES),
                "transient_errors": self.counter(FAULTS_TRANSIENT),
                "slow_batches": self.counter(FAULTS_SLOW),
                "failures": self.counter(MODEL_FAILURES),
                "detected": self.counter(FAULTS_DETECTED),
                "recovered": self.counter(FAULTS_RECOVERED),
                "requeued_queries": self.counter(FAULTS_REQUEUED),
                "retries": self.counter(FAULTS_RETRIES),
                "retry_exhausted": self.counter(FAULTS_RETRY_EXHAUSTED),
                "hedges": self.counter(FAULTS_HEDGES),
                "hedge_wins": self.counter(FAULTS_HEDGE_WINS),
            },
            "per_model": {
                m: {
                    "queries": self.counter(QUERIES_SUBMITTED, model=m),
                    # per-model prediction-cache counters (PredictionCache
                    # reports labeled hits/misses alongside the global pair)
                    "cache": self._model_cache(m),
                    # completions + end-to-end latency are tagged per model
                    # (LMServer does; the ensemble frontend completes
                    # queries across models, so these stay 0/empty there) —
                    # multi-model cluster reports can now separate LM
                    # completions from frontend ones
                    "completed": self.counter(QUERIES_COMPLETED, model=m),
                    "latency_s": self._hist_summary(LATENCY, model=m),
                    "batches": self.counter(BATCHES, model=m),
                    "service_s": self._hist_summary(SERVICE, model=m),
                    "batch_size": self._hist_summary(BATCH_SIZE, model=m),
                    # fault handling (DESIGN.md §14): injected failures this
                    # model's containers raised, plus the recovery work
                    # (re-dispatches, hedged duplicates) spent on it
                    "failures": self.counter(MODEL_FAILURES, model=m),
                    "retries": self.counter(FAULTS_RETRIES, model=m),
                    "hedges": self.counter(FAULTS_HEDGES, model=m),
                }
                for m in self._models()
            },
        }
        return rep

    def _model_cache(self, m: str) -> Dict[str, Any]:
        hits = self.counter(CACHE_HITS, model=m)
        misses = self.counter(CACHE_MISSES, model=m)
        return {
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / (hits + misses) if (hits + misses) else 0.0,
        }

    def report_json(self, stack: str, **extra: Any) -> str:
        """Stable JSON rendering — byte-identical for identical runs."""
        rep = self.report(stack)
        rep.update(extra)
        return json.dumps(rep, sort_keys=True, indent=2)
