"""Contextualization (paper §5.3): per-user / per-session selection state.

The paper keeps per-session bandit state in Redis. Here the store is a
device array ``[num_users, k]`` sharded over the batch axes of the mesh, and
feedback is applied in *batched, jitted, vmapped* updates — thousands of
users' Exp3/Exp4 states update in one SPMD step. The store checkpoints with
the rest of the system (fault tolerance) and re-shards elastically."""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import (
    exp3_observe, exp3_probs, exp4_combine, exp4_observe,
)
from repro.distributed.sharding import ShardingContext


class ContextualStore:
    """[num_users, k] bandit states with batched updates."""

    def __init__(self, num_users: int, k: int, *, kind: str = "exp4",
                 eta: float = 0.1, mesh=None, rules=None):
        self.num_users = num_users
        self.k = k
        self.kind = kind
        self.eta = eta
        sharding = None
        if mesh is not None and rules is not None:
            sharding = ShardingContext(mesh, rules).sharding(("users", None))
        self.states = (jax.device_put(jnp.zeros((num_users, k), jnp.float32),
                                      sharding)
                       if sharding else jnp.zeros((num_users, k), jnp.float32))
        self._sharding = sharding

        if kind == "exp3":
            self._batch_observe = jax.jit(
                jax.vmap(lambda s, c, l: exp3_observe(s, c, l, eta)))
        else:
            self._batch_observe = jax.jit(
                jax.vmap(lambda s, l, a: exp4_observe(s, l, eta, a)))

    def state_for(self, user: int) -> jax.Array:
        return self.states[user % self.num_users]

    def probs_for(self, user: int) -> np.ndarray:
        return np.asarray(exp3_probs(self.state_for(user)))

    # ---- batched feedback paths ----
    def observe_exp3(self, users: np.ndarray, chosen: np.ndarray,
                     losses: np.ndarray) -> None:
        u = jnp.asarray(users % self.num_users)
        new = self._batch_observe(self.states[u], jnp.asarray(chosen),
                                  jnp.asarray(losses, jnp.float32))
        self.states = self.states.at[u].set(new)

    def observe_exp4(self, users: np.ndarray, losses: np.ndarray,
                     available: Optional[np.ndarray] = None) -> None:
        u = jnp.asarray(users % self.num_users)
        if available is None:
            available = np.ones_like(losses, dtype=bool)
        new = self._batch_observe(self.states[u],
                                  jnp.asarray(losses, jnp.float32),
                                  jnp.asarray(available))
        self.states = self.states.at[u].set(new)

    def combine_for(self, user: int, preds_matrix, available=None):
        return exp4_combine(self.state_for(user), preds_matrix, available)

    # ---- checkpoint integration ----
    def state_dict(self):
        return {"states": np.asarray(self.states), "kind": self.kind,
                "eta": self.eta}

    def load_state_dict(self, d) -> None:
        states = jnp.asarray(d["states"])
        assert states.shape == (self.num_users, self.k)
        self.states = (jax.device_put(states, self._sharding)
                       if self._sharding else states)
