"""The Clipper frontend (paper §3): the application-facing serving loop that
composes the model abstraction layer (cache → adaptive batching → containers)
with the model selection layer (select → combine → observe, straggler-safe).

Implemented as a discrete-event loop with an injectable clock:

* wall-clock mode — containers execute for real and completion times come
  from measured execution (overhead benches, quickstart);
* calibrated-simulation mode — containers still execute (real outputs) but
  completion times come from their latency models, letting one CPU core
  faithfully replay cluster-scale scenarios (replica scaling, stragglers —
  paper Figs 6 & 9; documented in DESIGN.md §8).
"""

from __future__ import annotations

import heapq
import itertools
import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batching import AIMDController, BatchQueue
from repro.core.cache import PredictionCache
from repro.core.containers import (ContainerCrashed, JaxModelContainer,
                                   ReplicaSet, TransientError)
from repro.core.interfaces import Feedback, Prediction, Query
from repro.core.metrics import (CACHE_HITS, CACHE_MISSES, FAULTS_CRASHES,
                                FAULTS_DETECTED, FAULTS_HEDGE_WINS,
                                FAULTS_HEDGES, FAULTS_RECOVERED,
                                FAULTS_REQUEUED, FAULTS_RETRIES,
                                FAULTS_RETRY_EXHAUSTED, FAULTS_SLOW,
                                FAULTS_TRANSIENT, MetricsRegistry,
                                MODEL_FAILURES, PIPELINE_STAGES_DEGRADED,
                                PIPELINE_STAGES_SHED, QUERIES_COMPLETED,
                                QUERIES_DEGRADED, QUERIES_ROUTED,
                                QUERIES_SHED, QUERIES_SUBMITTED)
from repro.core.selection import Exp3Policy, Exp4Policy
from repro.core.straggler import assemble_preds, record_stragglers


@dataclass(order=True)
class _Event:
    at: float
    seq: int
    # 'complete' | 'deadline' | 'timeout' | 'hedge' | 'retry'
    kind: str = field(compare=False)
    payload: Any = field(compare=False, default=None)


class Clipper:
    """End-to-end prediction serving frontend."""

    def __init__(self, replica_sets: Dict[str, ReplicaSet], policy, *,
                 slo: float = 0.020, cache_size: int = 4096,
                 loss_fn: Optional[Callable[[Any, Any], float]] = None,
                 contextual_store=None, seed: int = 0,
                 use_cache: bool = True,
                 metrics: Optional[MetricsRegistry] = None,
                 router: Optional[Callable[[ReplicaSet, float], int]] = None,
                 admission=None, tracer=None, recovery=None, audit=None):
        self.replica_sets = replica_sets
        self.policy = policy
        self.slo = slo
        # failure detection + hedged-retry recovery (repro.faults,
        # DESIGN.md §14): None = recovery off. With no fault plan attached
        # either, dispatch takes the exact original path — zero per-query
        # overhead.
        self.recovery = recovery
        # control-plane hooks (repro.cluster, DESIGN.md §10): ``router``
        # maps (replica_set, now) -> replica index for each enqueue;
        # ``admission`` may narrow or reject the chosen ensemble per query
        self.router = router
        self.admission = admission
        # span tracing (repro.obs, DESIGN.md §13): None = tracing off, no
        # per-query overhead beyond these ``is not None`` checks
        self.tracer = tracer
        # control-plane decision audit (repro.obs.audit, DESIGN.md §15):
        # None = off, same zero-overhead discipline as the tracer
        self.audit = audit
        # fleet-sampler probe state: previous cumulative counter values,
        # touched only when a FleetSampler polls timeseries_probe
        self._ts_prev: Dict[str, float] = {}
        self.metrics = metrics if metrics is not None else MetricsRegistry(slo)
        self.cache = (PredictionCache(cache_size, metrics=self.metrics,
                                      tracer=tracer)
                      if use_cache else None)
        # batching + cache layers report through the same registry, so both
        # serving stacks emit one telemetry schema (metrics.py)
        for rs in replica_sets.values():
            rs.attach_metrics(self.metrics)
            if tracer is not None:
                rs.attach_tracer(tracer)
        self.loss_fn = loss_fn or _default_loss
        self.contextual = contextual_store
        self.rng = np.random.default_rng(seed)
        self.policy_state = policy.init()
        self._events: List[_Event] = []
        self._eseq = itertools.count()
        self._qseq = itertools.count()
        # in-flight batch registry for the failure detector: bid ->
        # {mid, ri, batch, at, done}. Only populated in recovery mode.
        self._batches: Dict[int, dict] = {}
        self._bseq = itertools.count()
        # (mid, ri) -> virtual time a recovery probe last cleared the
        # replica: timeouts of batches dispatched before that are stale
        # evidence and must not re-condemn the recovered replica
        self._cleared: Dict[Tuple[str, int], float] = {}
        self.now = 0.0
        self._pending: Dict[int, dict] = {}     # qid -> bookkeeping
        self.results: Dict[int, Prediction] = {}
        self.shed_qids: set = set()     # admission-rejected; never in results
        self._feedback_hits = 0
        self._feedback_misses = 0

    # ------------------------------------------------------------------
    # application API
    # ------------------------------------------------------------------
    def submit(self, x, *, context_id: int = 0,
               arrival_time: Optional[float] = None) -> int:
        """Issue a prediction request; returns the query id."""
        at = self.now if arrival_time is None else arrival_time
        self.now = max(self.now, at)
        self.metrics.inc(QUERIES_SUBMITTED)
        self.metrics.mark(at)
        qid = next(self._qseq)
        q = Query(qid, x, context_id, at, deadline=at + self.slo)
        trace = None
        if self.tracer is not None:
            # root span: the whole query lifecycle; budget = the full SLO
            trace = self.tracer.start_trace(
                "query", "frontend", at, budget_s=self.slo,
                attrs={"qid": qid})
        chosen = self.policy.select(self._policy_state_for(q), x, self.rng)
        cached, uncached = self._probe_and_admit(q, chosen, rescope=False,
                                                 trace=trace)
        if not uncached and not cached:
            # shed: never enqueued, never completes — callers checking
            # ``results[qid]`` must consult ``shed_qids`` first
            self.shed_qids.add(qid)
            if self.tracer is not None:
                self.tracer.end_trace(trace, self.now, status="shed")
            return qid
        entry = {"query": q, "need": set(cached) | set(uncached),
                 "preds": cached, "done": False, "trace": trace}
        self._start_entry(entry, uncached)
        return qid

    def submit_stage(self, model_ids: Sequence[str], x, *, deadline: float,
                     finalize: Callable[[Dict[str, Any], Tuple[str, ...], bool],
                                        None],
                     arrival_time: Optional[float] = None,
                     trace_parent=None) -> int:
        """Low-level stage job for DAG pipelines (repro.pipeline): evaluate
        ``x`` on ``model_ids`` under an absolute per-stage ``deadline`` and
        call ``finalize(preds, missing_models, at_deadline)`` exactly once —
        when every model returned, or at the deadline with whatever arrived
        (stage-level straggler mitigation, same semantics as ensembles).

        Stage jobs ride the ordinary machinery: the prediction cache is
        consulted first (this is the pipeline's intermediate-result cache —
        a hit skips the model entirely), admission control may narrow or
        shed the stage, and batching/routing are untouched. Unlike
        ``submit``, no global query counters move here: the pipeline
        executor accounts queries at pipeline granularity. A stage shed
        entirely with nothing cached finalizes immediately with empty preds
        — the executor decides what an empty stage means."""
        at = self.now if arrival_time is None else arrival_time
        self.now = max(self.now, at)
        self.metrics.mark(at)
        qid = next(self._qseq)
        q = Query(qid, x, 0, at, deadline=deadline)
        cached, uncached = self._probe_and_admit(q, model_ids, rescope=True,
                                                 trace=trace_parent)
        entry = {"query": q, "need": set(cached) | set(uncached),
                 "preds": cached, "done": False, "finalize": finalize,
                 "trace": trace_parent}
        self._start_entry(entry, uncached)
        return qid

    def _probe_and_admit(self, q: Query, model_ids: Sequence[str], *,
                         rescope: bool,
                         trace=None) -> Tuple[Dict[str, Any], List[str]]:
        """The cache-probe + admission core both submit paths share:
        returns ``(cached predictions, models still to evaluate)``.
        Admission (when configured) drops models — or everything — whose
        deadline is already unmeetable given the backlog (DESIGN.md §10).

        ``rescope=True`` (stage jobs) records admission's shed/degraded
        decisions under stage-level names, so ``admission.shed`` stays
        one-per-*pipeline*-query (the executor accounts those) and
        ``completed + shed == submitted`` keeps holding."""
        cached: Dict[str, Any] = {}
        uncached: List[str] = []
        for mid in model_ids:
            if self.cache is not None and self.cache.request(
                    mid, q.x, parent=trace, now=self.now):
                cached[mid] = self.cache.fetch(mid, q.x)
            else:
                uncached.append(mid)
        if self.admission is not None and uncached:
            counters = ({"shed_counter": PIPELINE_STAGES_SHED,
                         "degraded_counter": PIPELINE_STAGES_DEGRADED}
                        if rescope else {})
            uncached = self.admission.admit(self, q, uncached,
                                            cached=bool(cached),
                                            trace_parent=trace, **counters)
        return cached, uncached

    def _start_entry(self, entry: dict, uncached: Sequence[str]) -> None:
        """Register a pending entry, route its uncached models, arm the
        deadline, and finalize immediately if nothing needs computing."""
        q: Query = entry["query"]
        self._pending[q.query_id] = entry
        trace = entry.get("trace")
        if trace is not None:
            entry["tqueue"] = {}
        for mid in uncached:
            ri = self._route(mid, q)
            if trace is not None:
                # queue span opens at enqueue; _dispatch_ready closes it
                # when the query leaves the replica's batch queue. Routers
                # exposing ``last_attrs`` (LECT) annotate their prediction.
                attrs = {"model": mid, "replica": ri}
                attrs.update(getattr(self.router, "last_attrs", None) or {})
                entry["tqueue"][mid] = self.tracer.start_span(
                    trace, "queue", "frontend.queue", self.now, attrs=attrs)
        if uncached:
            self._push(q.deadline, "deadline", q.query_id)
        self._maybe_finalize(entry)

    def feedback(self, fb: Feedback) -> None:
        """Join feedback with cached predictions and update selection state
        (paper §4.2 + §5). Missing predictions are recomputed — the cost the
        cache exists to avoid."""
        preds: Dict[str, Any] = {}
        for mid, rs in self.replica_sets.items():
            y = self.cache.fetch(mid, fb.x) if self.cache is not None else None
            if y is None:
                self._feedback_misses += 1
                y = rs.replicas[0].pred_batch([fb.x])[0]
                if self.cache is not None:
                    self.cache.put(mid, fb.x, y)
            else:
                self._feedback_hits += 1
            preds[mid] = y
        losses = {mid: self.loss_fn(y, fb.y_true) for mid, y in preds.items()}
        if self.contextual is not None:
            self._observe_contextual(fb, losses)
        else:
            self.policy_state = self.policy.observe(
                self.policy_state, fb.x, losses, preds)

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------
    def run(self, until: Optional[float] = None) -> None:
        """Process events and dispatch ready batches until quiescent (or
        until the given virtual time)."""
        while True:
            self._dispatch_ready()
            if not self._events:
                break
            ev = heapq.heappop(self._events)
            if until is not None and ev.at > until:
                heapq.heappush(self._events, ev)
                break
            self.now = max(self.now, ev.at)
            if ev.kind == "complete":
                self._on_complete(**ev.payload)
            elif ev.kind == "deadline":
                self._on_deadline(ev.payload)
            elif ev.kind == "timeout":
                self._on_timeout(ev.payload)
            elif ev.kind == "hedge":
                self._on_hedge(ev.payload)
            elif ev.kind == "retry":
                self._on_retry(*ev.payload)

    def _dispatch_ready(self) -> None:
        recovering = self.recovery is not None
        if recovering:
            self._probe_recovered()
        progressed = True
        while progressed:
            progressed = False
            for mid, rs in self.replica_sets.items():
                for ri, queue in enumerate(rs.queues):
                    if not queue.ready(self.now):
                        continue
                    if (rs.free_at[ri] > self.now or rs.replicas[ri].fail
                            or rs.retired[ri]):
                        continue
                    batch = queue.next_batch(self.now)
                    if not batch:
                        continue
                    if recovering or rs.has_faults:
                        self._dispatch_fault_aware(mid, rs, ri, queue, batch)
                        progressed = True
                        continue
                    outs, service = rs.replicas[ri].pred_batch_timed(
                        [q.x for q in batch])
                    done_at = self.now + service
                    rs.free_at[ri] = done_at
                    if self.tracer is not None:
                        self._trace_dispatch(
                            mid, ri, batch, done_at,
                            getattr(queue.controller, "slo", None))
                    self._push(done_at, "complete", dict(
                        mid=mid, ri=ri, batch=batch, outs=outs,
                        service=service, size=len(batch)))
                    progressed = True

    # ------------------------------------------------------------------
    # fault handling (repro.faults, DESIGN.md §14)
    # ------------------------------------------------------------------
    def _dispatch_fault_aware(self, mid: str, rs: ReplicaSet, ri: int,
                              queue: BatchQueue,
                              batch: List[Query]) -> None:
        """Dispatch one batch on a replica that may crash, error, or run
        degraded. Failure semantics: a crash *silently loses* the batch
        (no completion event — only the armed timeout can notice), a
        transient error fails fast (retries schedule immediately), and a
        successful dispatch arms the detector timeout plus (optionally) a
        straggler hedge."""
        pol = self.recovery
        faults = rs.replicas[ri].faults
        if (faults is not None
                and faults.multiplier(self.now) != 1.0):
            self.metrics.inc_both(FAULTS_SLOW, model=mid)
        # arm-time thresholds come from *pre-dispatch* history: the
        # container's synchronous stats update would otherwise leak this
        # very batch's (possibly degraded) service time into the estimate,
        # inflating the detector/hedge deadlines it is supposed to police
        detect_in = (self._detect_after(rs, ri, len(batch), pol)
                     if pol is not None else 0.0)
        hedge_in = (self._hedge_after(rs, ri, len(batch), pol)
                    if pol is not None and pol.hedge else 0.0)
        try:
            outs, service = rs.replicas[ri].pred_batch_timed(
                [q.x for q in batch], now=self.now)
        except ContainerCrashed:
            self.metrics.inc_both(FAULTS_CRASHES, model=mid)
            self.metrics.inc_both(MODEL_FAILURES, model=mid)
            self._close_queue_spans(mid, batch)
            if self.tracer is not None:
                self.tracer.global_event(
                    "fault.crash", "faults", self.now,
                    attrs={"model": mid, "replica": ri,
                           "queries": len(batch)})
            if pol is not None:
                bid = next(self._bseq)
                self._batches[bid] = dict(mid=mid, ri=ri, batch=batch,
                                          at=self.now, done=False)
                self._push(self.now + detect_in, "timeout", bid)
            return
        except TransientError:
            self.metrics.inc_both(FAULTS_TRANSIENT, model=mid)
            self.metrics.inc_both(MODEL_FAILURES, model=mid)
            self._close_queue_spans(mid, batch)
            if self.tracer is not None:
                self.tracer.global_event(
                    "fault.transient", "faults", self.now,
                    attrs={"model": mid, "replica": ri,
                           "queries": len(batch)})
            if pol is not None:
                # fail-fast: the error response arrives immediately, so
                # retries back off from *now* rather than from detection
                self._schedule_retries(mid, batch)
            return
        done_at = self.now + service
        rs.free_at[ri] = done_at
        if self.tracer is not None:
            self._trace_dispatch(mid, ri, batch, done_at,
                                 getattr(queue.controller, "slo", None))
        bid = None
        if pol is not None:
            bid = next(self._bseq)
            self._batches[bid] = dict(mid=mid, ri=ri, batch=batch,
                                      at=self.now, done=False)
            self._push(self.now + detect_in, "timeout", bid)
            if pol.hedge:
                self._push(self.now + hedge_in, "hedge", bid)
        self._push(done_at, "complete", dict(
            mid=mid, ri=ri, batch=batch, outs=outs, service=service,
            size=len(batch), bid=bid))

    def _close_queue_spans(self, mid: str, batch: Sequence[Query]) -> None:
        """A failed dispatch still pulled the batch out of its queue: close
        the queue spans (truncated) so every started span ends. A later
        retry opens a fresh one."""
        if self.tracer is None:
            return
        for q in batch:
            entry = self._pending.get(q.query_id)
            if entry is None or entry.get("trace") is None:
                continue
            self.tracer.end_span(entry["tqueue"].pop(mid, None), self.now,
                                 truncated=True)

    def _detect_after(self, rs: ReplicaSet, ri: int, size: int,
                      pol) -> float:
        """Detector timeout for a batch of ``size`` dispatched now: a
        generous multiple of the batch's *expected completion* (per-query
        service estimate × batch size — est_service is per query, service
        is per batch), floored so cold replicas (no history) are not
        instantly condemned."""
        floor = pol.min_timeout if pol.min_timeout is not None else self.slo
        return max(pol.detect_factor * rs.est_service(ri, 0.0) * size, floor)

    def _hedge_after(self, rs: ReplicaSet, ri: int, size: int,
                     pol) -> float:
        floor = (pol.hedge_min if pol.hedge_min is not None
                 else self.slo / 2.0)
        return max(pol.hedge_factor * rs.est_service(ri, 0.0) * size, floor)

    def _probe_recovered(self) -> None:
        """Health-probe suspected replicas each dispatch round; recovered
        ones rejoin routing. While a suspected replica stays down, any work
        stranded on its queue (router fallback under total failure) drains
        to a live replica as soon as one exists."""
        for mid, rs in self.replica_sets.items():
            if not rs.suspected:
                continue
            for ri in rs.probe_recovered(self.now):
                self._cleared[(mid, ri)] = self.now
                self.metrics.inc_both(FAULTS_RECOVERED, model=mid)
                if self.tracer is not None:
                    self.tracer.global_event(
                        "fault.recovered", "faults", self.now,
                        attrs={"model": mid, "replica": ri})
                if self.audit is not None:
                    self.audit.record(self.now, "faults", "recover",
                                      model=mid, evidence={"replica": ri})
            for ri in sorted(rs.suspected):
                if rs.queues[ri]:
                    self._drain_suspect(mid, rs, ri)

    def _drain_suspect(self, mid: str, rs: ReplicaSet, ri: int) -> None:
        targets = [i for i in rs.routable() if i != ri]
        if not targets:
            return
        tgt = min(targets, key=lambda i: (len(rs.queues[i]), i))
        moved = rs.queues[ri].requeue_to(rs.queues[tgt],
                                         keep=self._query_live)
        if moved:
            self.metrics.inc_both(FAULTS_REQUEUED, n=moved, model=mid)

    def _query_live(self, q: Query) -> bool:
        entry = self._pending.get(q.query_id)
        return entry is not None and not entry["done"]

    def _on_timeout(self, bid: int) -> None:
        """A dispatched batch missed its expected completion: declare the
        replica down (out of routing until a health probe clears it), drain
        its queued backlog to a live replica, and retry the lost queries."""
        rec = self._batches.pop(bid, None)
        if rec is None or rec["done"]:
            return
        mid, ri = rec["mid"], rec["ri"]
        rs = self.replica_sets[mid]
        stale = rec["at"] < self._cleared.get((mid, ri), float("-inf"))
        if not stale and not rs.replicas[ri].fail:   # first detection wins
            rs.replicas[ri].fail = True
            rs.suspected.add(ri)
            self.metrics.inc_both(FAULTS_DETECTED, model=mid)
            if self.tracer is not None:
                self.tracer.global_event(
                    "fault.detected", "faults", self.now,
                    attrs={"model": mid, "replica": ri})
            if self.audit is not None:
                self.audit.record(
                    self.now, "faults", "detect", model=mid,
                    evidence={"replica": ri, "dispatched_at": rec["at"],
                              "batch": len(rec["batch"]),
                              "overdue_s": self.now - rec["at"]})
            self._drain_suspect(mid, rs, ri)
        self._schedule_retries(mid, rec["batch"])

    def _schedule_retries(self, mid: str, batch: Sequence[Query]) -> None:
        """Re-dispatch lost queries under the per-query per-model retry
        budget with exponential backoff; exhausted queries are left to
        straggler mitigation (render without the model at the deadline)."""
        pol = self.recovery
        if pol is None:
            return
        for q in batch:
            entry = self._pending.get(q.query_id)
            if (entry is None or entry["done"]
                    or mid in entry["preds"] or mid not in entry["need"]):
                continue
            tries = entry.setdefault("retries", {})
            n = tries.get(mid, 0)
            if n >= pol.max_retries:
                self.metrics.inc_both(FAULTS_RETRY_EXHAUSTED, model=mid)
                if self.tracer is not None and entry.get("trace") is not None:
                    self.tracer.event(entry["trace"], "retry_exhausted",
                                      "frontend.fault", self.now,
                                      attrs={"model": mid, "attempts": n})
                continue
            tries[mid] = n + 1
            self._push(self.now + pol.backoff_base * (2 ** n), "retry",
                       (mid, q.query_id))

    def _on_retry(self, mid: str, qid: int) -> None:
        entry = self._pending.get(qid)
        if entry is None or entry["done"] or mid in entry["preds"]:
            return
        self.metrics.inc_both(FAULTS_RETRIES, model=mid)
        q: Query = entry["query"]
        if self.audit is not None:
            self.audit.record(
                self.now, "faults", "retry", model=mid,
                evidence={"qid": qid, "attempt": entry["retries"][mid],
                          "slack_s": (q.deadline - self.now
                                      if q.deadline is not None else None)})
        ri = self._route(mid, q)
        if self.tracer is not None and entry.get("trace") is not None:
            self.tracer.event(entry["trace"], "retry", "frontend.fault",
                              self.now, attrs={"model": mid, "replica": ri,
                                               "attempt":
                                               entry["retries"][mid]})
            old = entry["tqueue"].pop(mid, None)
            self.tracer.end_span(old, self.now, truncated=True)
            entry["tqueue"][mid] = self.tracer.start_span(
                entry["trace"], "queue", "frontend.queue", self.now,
                attrs={"model": mid, "replica": ri, "retry": True})

    def _on_hedge(self, bid: int) -> None:
        """The batch outlived its hedge threshold but is not (yet) presumed
        dead: re-enqueue its unanswered queries once on the best alternate
        replica; whichever copy completes first wins."""
        rec = self._batches.get(bid)
        if rec is None or rec["done"]:
            return
        mid, ri = rec["mid"], rec["ri"]
        rs = self.replica_sets[mid]
        alts = [i for i in rs.routable() if i != ri]
        if not alts:
            return
        alt = min(alts, key=lambda i: (rs.expected_completion(i, self.now),
                                       len(rs.queues[i]), i))
        hedged = 0
        for q in rec["batch"]:
            entry = self._pending.get(q.query_id)
            if (entry is None or entry["done"] or mid in entry["preds"]
                    or mid in entry.get("hedge_from", {})):
                continue            # one hedge per query per model
            entry.setdefault("hedge_from", {})[mid] = ri
            rs.queues[alt].put(q)
            hedged += 1
            self.metrics.inc_both(FAULTS_HEDGES, model=mid)
            if self.tracer is not None and entry.get("trace") is not None:
                self.tracer.event(entry["trace"], "hedge", "frontend.fault",
                                  self.now,
                                  attrs={"model": mid, "from": ri,
                                         "to": alt})
                if entry["tqueue"].get(mid) is None:
                    entry["tqueue"][mid] = self.tracer.start_span(
                        entry["trace"], "queue", "frontend.queue", self.now,
                        attrs={"model": mid, "replica": alt, "hedge": True})
        if hedged and self.audit is not None:
            self.audit.record(
                self.now, "faults", "hedge", model=mid,
                evidence={"from": ri, "to": alt, "queries": hedged,
                          "batch_age_s": self.now - rec["at"],
                          "alt_ect_s": rs.expected_completion(alt, self.now)})

    def _trace_dispatch(self, mid: str, ri: int, batch: Sequence[Query],
                        done_at: float, budget: Optional[float]) -> None:
        """Per-query trace bookkeeping at batch dispatch: close the queue
        span, record the service span (budget = the batch controller's
        latency target), and remember dispatch/completion times for
        finalize-time attribution."""
        for q in batch:
            entry = self._pending.get(q.query_id)
            if entry is None or entry.get("trace") is None:
                continue
            self.tracer.end_span(entry["tqueue"].pop(mid, None), self.now)
            self.tracer.add_span(
                entry["trace"], "service", "frontend.service", self.now,
                done_at, budget_s=budget,
                attrs={"model": mid, "replica": ri, "batch": len(batch)})
            if mid not in entry["preds"]:
                # a hedged duplicate dispatching after the primary already
                # answered must not overwrite the winner's timestamps —
                # attribution walks the *used* prediction's critical path
                entry.setdefault("tdisp", {})[mid] = self.now
                entry.setdefault("tdone", {})[mid] = done_at

    def _on_complete(self, mid, ri, batch, outs, service, size,
                     bid=None) -> None:
        if bid is not None:
            rec = self._batches.pop(bid, None)
            if rec is not None:
                rec["done"] = True
        rs = self.replica_sets[mid]
        rs.queues[ri].record(size, service)
        recovering = self.recovery is not None
        for q, y in zip(batch, outs):
            if self.cache is not None:
                self.cache.put(mid, q.x, y)
            entry = self._pending.get(q.query_id)
            if entry is None or entry["done"]:
                continue                      # already straggler-finalized
            if recovering:
                if mid in entry["preds"]:
                    continue          # first result won; drop the duplicate
                hedged_from = entry.get("hedge_from", {}).get(mid)
                if hedged_from is not None and hedged_from != ri:
                    self.metrics.inc_both(FAULTS_HEDGE_WINS, model=mid)
                if entry.get("trace") is not None:
                    # the winner's timestamps, whichever copy it was —
                    # keeps queue + service + straggler_wait == latency
                    # exact even when a hedge beats its primary
                    entry.setdefault("tdisp", {})[mid] = self.now - service
                    entry.setdefault("tdone", {})[mid] = self.now
            entry["preds"][mid] = y
            self._maybe_finalize(entry)

    def _on_deadline(self, qid: int) -> None:
        entry = self._pending.get(qid)
        if entry is None or entry["done"]:
            return
        # no predictions at all: mark late and leave pending; the *first*
        # model to return then renders immediately (latency SLO already
        # blown — recorded as violation) instead of waiting for the rest
        entry["late"] = True
        if self.tracer is not None and entry.get("trace") is not None:
            self.tracer.event(entry["trace"], "deadline", "frontend.slo",
                              self.now)
        if entry["preds"] or entry.get("finalize") is not None:
            # stage jobs finalize at the deadline with whatever arrived —
            # possibly nothing (every model crashed with its retries
            # exhausted): the executor must learn the stage failed rather
            # than wait forever on a completion that cannot come
            self._finalize(entry, at_deadline=True)

    def _maybe_finalize(self, entry) -> None:
        if entry["done"]:
            return
        if entry["need"] <= set(entry["preds"]):
            self._finalize(entry, at_deadline=False)
        elif entry.get("late") and entry["preds"]:
            # past the deadline with nothing rendered yet: a late partial
            # answer beats waiting out the stragglers (paper §5.2.2)
            self._finalize(entry, at_deadline=True)

    def _finalize(self, entry, *, at_deadline: bool) -> None:
        q: Query = entry["query"]
        preds = {m: p for m, p in entry["preds"].items()}
        # finalized entries leave the pending map — late completions find
        # nothing and skip (they still feed the cache); without this the
        # map grows with every query served, ~4x faster for stage jobs
        self._pending.pop(q.query_id, None)
        trace = entry.get("trace")
        if trace is not None:
            # models still queued at render time never served this query:
            # close their queue spans truncated (every started span ends)
            for span in entry.get("tqueue", {}).values():
                self.tracer.end_span(span, self.now, truncated=True)
            entry["tqueue"] = {}
        fin = entry.get("finalize")
        if fin is not None:
            # stage job (submit_stage): hand the arrived predictions to the
            # pipeline executor; global query accounting — and the stage
            # span wrapping this job — stay with it
            entry["done"] = True
            self.metrics.mark(self.now)
            fin(preds, tuple(sorted(entry["need"] - set(preds))), at_deadline)
            return
        s = self._policy_state_for(q)
        y, conf = self.policy.combine(s, q.x, preds)
        missing = tuple(sorted(entry["need"] - set(preds)))
        entry["done"] = True
        latency = self.now - q.arrival_time
        if trace is not None:
            self._end_query_trace(entry, q, latency, missing, at_deadline)
        self.metrics.mark(self.now)
        self.metrics.inc(QUERIES_COMPLETED)
        self.metrics.observe_latency(latency)
        record_stragglers(self.metrics, missing)
        self.results[q.query_id] = Prediction(
            q.query_id, y, conf, tuple(sorted(preds)),
            latency=latency,
            missing_models=missing)

    def _end_query_trace(self, entry, q: Query, latency: float,
                         missing: Tuple[str, ...],
                         at_deadline: bool) -> None:
        """Exact latency attribution (DESIGN.md §13): partition end-to-end
        latency along the *critical model* — the used prediction that
        finished last. queue + service + straggler_wait == latency, so the
        run-level fractions sum to 1."""
        done = {m: t for m, t in entry.get("tdone", {}).items()
                if m in entry["preds"]}
        attribution = None
        if latency > 0:
            if done:
                crit = max(done, key=lambda m: (done[m], m))
                attribution = {
                    "frontend.queue": entry["tdisp"][crit] - q.arrival_time,
                    "frontend.service": done[crit] - entry["tdisp"][crit],
                    "frontend.straggler_wait": self.now - done[crit],
                }
                if self.now > done[crit]:
                    self.tracer.add_span(
                        entry["trace"], "straggler_wait",
                        "frontend.straggler", done[crit], self.now,
                        attrs={"critical_model": crit})
            else:
                # rendered from cache alone at the deadline: every moment
                # of the latency was spent waiting on stragglers
                attribution = {"frontend.straggler_wait": latency}
        self.tracer.end_trace(
            entry["trace"], self.now, attribution=attribution,
            status="deadline" if at_deadline else "ok",
            attrs={"missing": len(missing)})

    # ------------------------------------------------------------------
    def _policy_state_for(self, q: Query):
        if self.contextual is not None:
            return self.contextual.state_for(q.context_id)
        return self.policy_state

    def _observe_contextual(self, fb: Feedback, losses: Dict[str, float]):
        ids = list(self.policy.model_ids)
        lvec = np.asarray([losses.get(m, 0.0) for m in ids], np.float32)
        if isinstance(self.policy, Exp3Policy):
            i = int(np.argmin(lvec))  # feedback for evaluated model only
            self.contextual.observe_exp3(np.asarray([fb.context_id]),
                                         np.asarray([i]), lvec[i:i + 1])
        else:
            self.contextual.observe_exp4(np.asarray([fb.context_id]),
                                         lvec[None, :])

    def _route(self, mid: str, q: Query) -> int:
        """Enqueue on the replica the router picks (default: least-loaded
        among routable replicas) and count the routed demand — the arrival
        signal the autoscaler's queueing model samples. Returns the chosen
        replica index (trace annotation)."""
        rs = self.replica_sets[mid]
        if self.router is not None:
            ri = self.router(rs, self.now)
        else:
            ri = min(rs.candidates(), key=lambda i: len(rs.queues[i]))
        if self.audit is not None:
            # decision-time evidence: the queue the router saw, plus the
            # router's own prediction when it exposes one (LECT's ect_s)
            ev = {"replica": ri, "queue_depth": len(rs.queues[ri]),
                  "free_in_s": max(rs.free_at[ri] - self.now, 0.0)}
            ev.update(getattr(self.router, "last_attrs", None) or {})
            self.audit.record(self.now, "router", "pick", model=mid,
                              evidence=ev)
        rs.queues[ri].put(q)
        self.metrics.inc(QUERIES_ROUTED, model=mid)
        return ri

    def _push(self, at: float, kind: str, payload) -> None:
        heapq.heappush(self._events, _Event(at, next(self._eseq), kind, payload))

    def replay(self, trace: Sequence[Tuple[float, Any, int]]) -> List[int]:
        """Open-loop replay of an arrival trace [(arrival_time, x, context_id)]
        — events are processed *between* arrivals so the virtual clock
        advances realistically. Returns query ids in order."""
        qids = []
        for at, x, ctx in trace:
            self.run(until=at)
            qids.append(self.submit(x, context_id=ctx, arrival_time=at))
        self.run()
        return qids

    # ------------------------------------------------------------------
    @property
    def pending(self) -> bool:
        """True while any event is scheduled or any query sits in a replica
        queue — the external drive predicate (the control-plane loop uses
        this, not the private event heap)."""
        if self._events:
            return True
        return any(len(queue) > 0 for rs in self.replica_sets.values()
                   for queue in rs.queues)

    @property
    def feedback_cache_hit_rate(self) -> float:
        tot = self._feedback_hits + self._feedback_misses
        return self._feedback_hits / tot if tot else 0.0

    # ------------------------------------------------------------------
    # fleet telemetry (repro.obs.timeseries, DESIGN.md §15)
    # ------------------------------------------------------------------
    def _rate(self, key: str, cur: float, dt: float) -> float:
        """Per-interval rate from a cumulative counter (probe state)."""
        prev = self._ts_prev.get(key, 0.0)
        self._ts_prev[key] = cur
        return (cur - prev) / dt

    def timeseries_probe(self, now: float, dt: float) -> Dict[str, float]:
        """FleetSampler probe: one flat gauge snapshot of the frontend's
        vital signs. Windowed rates (λ, cache hit rate, shed/degrade) are
        cumulative-counter deltas over the sample interval — the probe is
        stateful across samples but read-only on the run, so an observed
        run stays byte-identical to an unobserved one."""
        m = self.metrics
        out: Dict[str, float] = {
            "lambda": self._rate("lambda", m.counter(QUERIES_SUBMITTED), dt),
            "throughput": self._rate("done", m.counter(QUERIES_COMPLETED),
                                     dt),
            "admission.shed_rate": self._rate(
                "shed", m.counter(QUERIES_SHED), dt),
            "admission.degrade_rate": self._rate(
                "degraded", m.counter(QUERIES_DEGRADED), dt),
        }
        if self.cache is not None:
            hits, misses = m.counter(CACHE_HITS), m.counter(CACHE_MISSES)
            dh = hits - self._ts_prev.get("cache.hits", 0)
            dm = misses - self._ts_prev.get("cache.misses", 0)
            self._ts_prev["cache.hits"] = hits
            self._ts_prev["cache.misses"] = misses
            out["cache.occupancy"] = float(len(self.cache))
            out["cache.hit_rate"] = dh / (dh + dm) if (dh + dm) else 0.0
        for mid, rs in sorted(self.replica_sets.items()):
            backlog = sum(len(q) for i, q in enumerate(rs.queues)
                          if not rs.retired[i])
            inflight = sum(1 for i in range(len(rs.replicas))
                           if rs.free_at[i] > now and not rs.retired[i])
            budgets = [rs.queues[i].controller.max_batch_size
                       for i in rs.routable()]
            out[f"queue_depth.{mid}"] = float(backlog)
            out[f"inflight.{mid}"] = float(inflight)
            out[f"replicas_live.{mid}"] = float(rs.n_live)
            out[f"replicas_draining.{mid}"] = float(sum(rs.draining))
            out[f"replicas_failed.{mid}"] = float(
                sum(1 for r in rs.replicas if r.fail))
            out[f"replicas_suspected.{mid}"] = float(len(rs.suspected))
            out[f"est_service.{mid}"] = rs.mean_service()
            out[f"aimd_budget.{mid}"] = (
                sum(budgets) / len(budgets) if budgets else 0.0)
            out[f"lambda.{mid}"] = self._rate(
                f"routed.{mid}", m.counter(QUERIES_ROUTED, model=mid), dt)
        return out

    def report(self) -> Dict[str, Any]:
        """Canonical telemetry report (metrics.py schema, shared with
        LMServer). With a tracer attached the report gains the run-level
        ``latency_attribution`` (fractions of end-to-end latency per
        component, exact under a virtual clock) and a ``trace`` summary."""
        rep = self.metrics.report("frontend")
        dur = self.metrics.duration
        per_model = rep.get("per_model") or {}
        for mid, rs in sorted(self.replica_sets.items()):
            row = per_model.get(mid)
            if row is None:
                continue
            # busy-time / wall-time per replica: which copies actually
            # carried the load (capacity-planning evidence, DESIGN.md §15)
            row["replicas"] = [
                {"replica": st["replica"],
                 "busy_time": st["busy_time"],
                 "utilization": st["busy_time"] / dur if dur > 0 else 0.0,
                 "queries": st["queries"],
                 "retired": st["retired"]}
                for st in rs.replica_stats()]
        if self.tracer is not None:
            rep["latency_attribution"] = self.tracer.attribution_report()
            rep["trace"] = self.tracer.summary()
        return rep

    def report_json(self, **extra: Any) -> str:
        rep = self.report()
        rep.update(extra)
        return json.dumps(rep, sort_keys=True, indent=2)


def _default_loss(y, y_true) -> float:
    """0/1 loss on argmax for class scores; absolute error otherwise.

    Pipeline combine stages produce *structured* predictions — a
    ``{"y": scores, "confidence": ...}`` dict or a ``(scores, ...)`` tuple —
    which ``np.asarray`` would mangle (object arrays, ragged errors). Unwrap
    them to the payload first: dicts by their ``"y"`` key (else the first
    sorted key), tuples by their first element."""
    while isinstance(y, (dict, tuple)):
        if isinstance(y, dict):
            if not y:
                raise ValueError("empty dict prediction has no loss")
            y = y["y"] if "y" in y else y[sorted(y)[0]]
        else:
            if not y:
                raise ValueError("empty tuple prediction has no loss")
            y = y[0]
    y = np.asarray(y)
    if y.ndim >= 1 and y.size > 1:
        return float(np.argmax(y) != np.asarray(y_true))
    return float(min(1.0, abs(float(y) - float(y_true))))


def make_clipper(models: Dict[str, Callable], policy_kind: str = "exp4", *,
                 slo: float = 0.020, replicas: int = 1,
                 latency_models: Optional[Dict[str, Any]] = None,
                 batch_delay: float = 0.0, cache_size: int = 4096,
                 aimd_kwargs: Optional[dict] = None,
                 **kw) -> Clipper:
    """Convenience constructor: plain predict fns -> containers -> Clipper."""
    aimd_kwargs = aimd_kwargs or {}
    sets = {}
    for mid, fn in models.items():
        lm = (latency_models or {}).get(mid)
        reps = [JaxModelContainer(mid, fn, latency_model=lm)
                for _ in range(replicas)]
        sets[mid] = ReplicaSet(
            reps, lambda: AIMDController(slo, **aimd_kwargs), batch_delay)
    ids = sorted(models)
    policy = Exp3Policy(ids) if policy_kind == "exp3" else Exp4Policy(ids)
    return Clipper(sets, policy, slo=slo, cache_size=cache_size, **kw)
