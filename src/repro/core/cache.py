"""Prediction cache with CLOCK eviction (paper §4.2).

The cache is a function cache for ``predict(m, x) -> y`` keyed by
``(model_id, digest(x))``. It exposes the paper's *non-blocking* request /
fetch API: ``request`` registers interest and reports presence without
computing; ``fetch`` returns the value if present. Because adaptive model
selection happens *above* the cache, selection changes never invalidate
entries (paper §4.2, last paragraph).

It also powers the feedback join (§5): predictions rendered moments ago are
re-fetched when feedback arrives, avoiding model re-evaluation — the paper's
1.6x feedback-throughput effect, reproduced in benchmarks/bench_cache.py."""

from __future__ import annotations

import hashlib
from typing import Any, Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.core import metrics as M


def digest(x: Any) -> Hashable:
    """Stable digest of a query input (arrays hashed by content).

    Non-array leaves carry their type name: Python hashes ``1``, ``1.0``
    and ``True`` identically, so without it those collide as cache keys —
    and a ``list`` input would collide with the same-valued ``tuple``."""
    if isinstance(x, np.ndarray):
        return hashlib.blake2b(
            x.tobytes() + str(x.shape).encode() + str(x.dtype).encode(),
            digest_size=16).hexdigest()
    if isinstance(x, (list, tuple)):
        return (type(x).__name__,) + tuple(digest(v) for v in x)
    return (type(x).__name__, x)


class ClockCache:
    """Fixed-capacity cache with the CLOCK (second-chance) eviction policy.

    O(1) get/put amortized; the hand skips referenced entries once, clearing
    their reference bit — the standard approximation of LRU the paper cites
    [Corbato '68]."""

    def __init__(self, capacity: int):
        assert capacity > 0
        self.capacity = capacity
        self._slots: List[Optional[Hashable]] = [None] * capacity
        self._ref: np.ndarray = np.zeros(capacity, dtype=bool)
        self._values: Dict[Hashable, Tuple[int, Any]] = {}   # key -> (slot, value)
        self._hand = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._values)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._values

    # --- paper's non-blocking API ---
    def request(self, key: Hashable) -> bool:
        """True if present (marks referenced); False means the caller should
        schedule computation and later ``put``."""
        entry = self._values.get(key)
        if entry is not None:
            self._ref[entry[0]] = True
            self.hits += 1
            return True
        self.misses += 1
        return False

    def fetch(self, key: Hashable) -> Optional[Any]:
        entry = self._values.get(key)
        if entry is None:
            return None
        self._ref[entry[0]] = True
        return entry[1]

    def put(self, key: Hashable, value: Any) -> None:
        entry = self._values.get(key)
        if entry is not None:                       # update in place
            self._values[key] = (entry[0], value)
            self._ref[entry[0]] = True
            return
        slot = self._find_slot()
        old_key = self._slots[slot]
        if old_key is not None:
            del self._values[old_key]
            self.evictions += 1
        self._slots[slot] = key
        self._values[key] = (slot, value)
        # classic CLOCK: new entries start unreferenced — they get one sweep
        # cycle to prove themselves, so churn can't flush referenced hot keys
        self._ref[slot] = False

    def _find_slot(self) -> int:
        if len(self._values) < self.capacity:
            # fast path: first empty slot from the hand
            for _ in range(self.capacity):
                if self._slots[self._hand] is None:
                    slot = self._hand
                    self._hand = (self._hand + 1) % self.capacity
                    return slot
                self._hand = (self._hand + 1) % self.capacity
        # CLOCK sweep: skip referenced entries once, clearing their bit
        while True:
            if self._ref[self._hand]:
                self._ref[self._hand] = False
                self._hand = (self._hand + 1) % self.capacity
            else:
                slot = self._hand
                self._hand = (self._hand + 1) % self.capacity
                return slot

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class PredictionCache:
    """(model_id, digest(x)) -> prediction, on top of ClockCache.

    When a ``MetricsRegistry`` is attached, every ``request`` is reported as
    a ``cache.hits`` / ``cache.misses`` increment — both globally and under
    the model's label, so ``report()['per_model'][m]['cache']`` breaks the
    hit rate down per model (the shared telemetry schema both stacks emit).
    The same mechanism serves as the pipeline *intermediate-result* cache:
    stage inputs are digested like any query, so two pipelines sharing a
    stage (same model id, same stage input) compute it once."""

    def __init__(self, capacity: int, metrics=None, tracer=None):
        self.cache = ClockCache(capacity)
        self.metrics = metrics
        # span tracing (repro.obs): probes annotate the querying trace
        self.tracer = tracer

    def __len__(self) -> int:
        return len(self.cache)

    def key(self, model_id: str, x: Any) -> Hashable:
        return (model_id, digest(x))

    def request(self, model_id: str, x: Any, *, parent=None,
                now: float = 0.0) -> bool:
        hit = self.cache.request(self.key(model_id, x))
        if self.metrics is not None:
            self.metrics.inc_both(M.CACHE_HITS if hit else M.CACHE_MISSES,
                                  model=model_id)
        if self.tracer is not None and parent is not None:
            # instant event under the query's root span: cache probes are
            # zero-duration in virtual time but decide the whole lifecycle
            self.tracer.event(parent, "hit" if hit else "miss",
                              "frontend.cache", now,
                              attrs={"model": model_id})
        return hit

    def fetch(self, model_id: str, x: Any) -> Optional[Any]:
        return self.cache.fetch(self.key(model_id, x))

    def put(self, model_id: str, x: Any, y: Any) -> None:
        self.cache.put(self.key(model_id, x), y)

    @property
    def hit_rate(self) -> float:
        return self.cache.hit_rate
