"""Model selection layer (paper §5): Exp3 single-model selection and Exp4
ensemble selection, as pure-JAX state updates.

States are plain arrays so the contextual store (§5.3) can hold one state
per user, shard them across the mesh, and apply feedback in batched, jitted,
vmapped updates — the TPU-native replacement for the paper's Redis-backed
per-session state (DESIGN.md §2)."""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Exp3 (paper §5.1) — pure functions over a log-weight state [k]
# ---------------------------------------------------------------------------

def exp3_init(k: int) -> jax.Array:
    return jnp.zeros((k,), jnp.float32)          # log weights

def exp3_probs(s: jax.Array) -> jax.Array:
    return jax.nn.softmax(s)

def exp3_select(s: jax.Array, rng_key) -> jax.Array:
    """Sample a model index from the Exp3 distribution."""
    return jax.random.categorical(rng_key, s)

LOG_WEIGHT_FLOOR = -20.0   # bounded pessimism: caps how far a model can fall
                           # behind, so recovery after healing is fast (the
                           # Fixed-Share-style behaviour visible in Fig 8)


@functools.partial(jax.jit, static_argnames=())
def exp3_observe(s: jax.Array, chosen: jax.Array, loss: jax.Array,
                 eta: float = 0.1) -> jax.Array:
    """w_i <- w_i * exp(-eta * L / p_i) for the selected model i."""
    p = exp3_probs(s)
    upd = -eta * loss / jnp.maximum(p[chosen], 1e-6)
    s = s.at[chosen].add(upd)
    s = s - jax.nn.logsumexp(s)                  # renormalize for stability
    return jnp.maximum(s, LOG_WEIGHT_FLOOR)


# ---------------------------------------------------------------------------
# Exp4 (paper §5.2) — ensemble weights with per-model losses
# ---------------------------------------------------------------------------

def exp4_init(k: int) -> jax.Array:
    return jnp.zeros((k,), jnp.float32)

def exp4_weights(s: jax.Array) -> jax.Array:
    return jax.nn.softmax(s)

def exp4_combine(s: jax.Array, preds: jax.Array,
                 available: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Weighted combination of base predictions.

    preds: [k, C] per-model class scores (or [k] scalars). available: [k]
    bool mask (straggler mitigation §5.2.2). Returns (combined, confidence)
    where confidence = weighted fraction of available models that agree with
    the final argmax (paper §5.2.1)."""
    w = exp4_weights(s)
    if available is not None:
        w = w * available
        w = w / jnp.maximum(w.sum(), 1e-9)
    combined = jnp.einsum("k,k...->...", w, preds.astype(jnp.float32))
    if preds.ndim > 1:
        final = jnp.argmax(combined, axis=-1)
        votes = jnp.argmax(preds, axis=-1)           # [k]
        agree = (votes == final).astype(jnp.float32)
    else:
        agree = jnp.ones_like(w)
    mask = available if available is not None else jnp.ones_like(w)
    conf = jnp.sum(agree * mask) / jnp.maximum(jnp.sum(mask), 1e-9)
    return combined, conf

@functools.partial(jax.jit, static_argnames=())
def exp4_observe(s: jax.Array, losses: jax.Array, eta: float = 0.1,
                 available: Optional[jax.Array] = None) -> jax.Array:
    """Down-weight each model by its own loss (losses in [0,1], [k])."""
    if available is not None:
        losses = jnp.where(available, losses, 0.0)   # no update for missing
    s = s - eta * losses
    s = s - jax.nn.logsumexp(s)
    return jnp.maximum(s, LOG_WEIGHT_FLOOR)


# ---------------------------------------------------------------------------
# policy objects implementing the paper's Listing-2 interface
# ---------------------------------------------------------------------------

@dataclass
class Exp3Policy:
    """Single-model selection: one model evaluated per query (cheap)."""

    model_ids: Sequence[str]
    eta: float = 0.1

    def init(self):
        return exp3_init(len(self.model_ids))

    def select(self, s, x, rng: np.random.Generator) -> List[str]:
        p = np.asarray(exp3_probs(s))
        i = int(rng.choice(len(p), p=p / p.sum()))
        return [self.model_ids[i]]

    def combine(self, s, x, preds: Dict[str, Any]):
        (mid, y), = preds.items()
        return y, 1.0

    def observe(self, s, x, loss_by_model: Dict[str, float], preds):
        (mid, loss), = loss_by_model.items()
        i = self.model_ids.index(mid)
        return exp3_observe(s, jnp.int32(i), jnp.float32(loss), self.eta)


@dataclass
class Exp4Policy:
    """Ensemble selection: all models evaluated, predictions combined
    (paper §5.2); supports straggler-masked combine (§5.2.2)."""

    model_ids: Sequence[str]
    eta: float = 0.1

    def init(self):
        return exp4_init(len(self.model_ids))

    def select(self, s, x, rng) -> List[str]:
        return list(self.model_ids)

    def combine(self, s, x, preds: Dict[str, Any]):
        if len(preds) == 1:
            # single prediction: pass through unchanged (weighted mean of
            # one element) — also lets structured dict/tuple outputs from
            # pipeline-style containers ride the plain frontend
            (_, y), = preds.items()
            return y, 1.0
        # pure-numpy hot path: this runs per query on the frontend host —
        # a per-query jitted-JAX dispatch would dominate serving overhead
        # (batched/vmapped state *updates* stay in JAX: context.py)
        w = np.exp(np.asarray(s, np.float64))
        avail = np.asarray([m in preds for m in self.model_ids])
        w = w * avail
        w = w / max(w.sum(), 1e-12)
        mean = np.mean([np.asarray(preds[m], np.float32)
                        for m in self.model_ids if m in preds], axis=0)
        mat = np.stack([np.asarray(preds[m], np.float32) if m in preds
                        else mean for m in self.model_ids])
        combined = np.einsum("k,k...->...", w, mat)
        if mat.ndim > 1:
            votes = mat.argmax(-1)
            conf = float(((votes == combined.argmax(-1)) & avail).sum()
                         / max(avail.sum(), 1))
        else:
            conf = 1.0
        return combined, conf

    def observe(self, s, x, loss_by_model: Dict[str, float], preds):
        losses = jnp.asarray([loss_by_model.get(m, 0.0) for m in self.model_ids],
                             jnp.float32)
        avail = jnp.asarray([m in loss_by_model for m in self.model_ids])
        return exp4_observe(s, losses, self.eta, avail)
