"""Straggler mitigation (paper §5.2.2).

Design choice from the paper: a *late* prediction is worse than an
*inaccurate* one. At the query's latency deadline the combine function is
invoked with the subset of predictions that arrived; missing models are
mean-substituted and the confidence score communicates the loss of ensemble
width. The masked math lives here (pure / jittable); the deadline scheduling
lives in the serving engine and frontend."""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M


def assemble_preds(model_ids: Sequence[str], preds: Dict[str, Any]
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stack per-model predictions into [k, ...], mean-substituting missing
    models (paper: 'we substitute missing predictions with their average
    value'). Returns (matrix, available mask)."""
    available = np.asarray([m in preds for m in model_ids])
    if not available.any():
        raise ValueError("no predictions available at deadline")
    vals = [np.asarray(preds[m], dtype=np.float32)
            for m in model_ids if m in preds]
    mean = np.mean(vals, axis=0)
    rows = [np.asarray(preds[m], np.float32) if m in preds else mean
            for m in model_ids]
    return jnp.asarray(np.stack(rows)), jnp.asarray(available)


def render_without(model_ids: Sequence[str], preds: Dict[str, Any],
                   without: Sequence[str]) -> np.ndarray:
    """The ensemble answer rendered as if ``without`` models never replied —
    the degraded output a query falls back to when a model's replicas have
    failed past their retry budget (DESIGN.md §14). Pure function of the
    surviving predictions: averaging only the available rows (the masked
    mean ``assemble_preds`` callers compute), so repeated renders from the
    same survivors are deterministic."""
    kept = {m: p for m, p in preds.items() if m not in set(without)}
    mat, avail = assemble_preds(model_ids, kept)
    mask = avail.reshape((-1,) + (1,) * (mat.ndim - 1))
    y = jnp.where(mask, mat, 0.0).sum(axis=0) / jnp.maximum(avail.sum(), 1)
    return np.asarray(y)


def agreement_confidence(preds_matrix: jnp.ndarray,
                         available: jnp.ndarray) -> float:
    """Fraction of available models that agree with the plurality vote."""
    votes = jnp.argmax(preds_matrix, axis=-1)
    combined = jnp.argmax(
        jnp.mean(jnp.where(available[:, None], preds_matrix, 0.0), axis=0))
    agree = (votes == combined) & available
    return float(agree.sum() / jnp.maximum(available.sum(), 1))


def record_stragglers(metrics, missing_models: Sequence[str]) -> None:
    """Single accounting convention for straggler mitigation, shared by both
    serving stacks: one ``straggler.partial_queries`` per degraded query,
    ``straggler.dropped_models`` per missing ensemble member."""
    if metrics is None or not missing_models:
        return
    metrics.inc(M.STRAGGLER_PARTIAL)
    metrics.inc(M.STRAGGLER_DROPPED, len(missing_models))


class DeadlineTracker:
    """Book-keeping for per-query deadlines in the serving loop."""

    def __init__(self, slo: float):
        self.slo = slo

    def deadline_for(self, arrival_time: float) -> float:
        return arrival_time + self.slo

    def expired(self, arrival_time: float, now: float) -> bool:
        return now >= self.deadline_for(arrival_time)

    def remaining(self, arrival_time: float, now: float) -> float:
        return max(0.0, self.deadline_for(arrival_time) - now)
