"""Model containers (paper §4.4).

``JaxModelContainer`` wraps any jitted predict function behind the uniform
``pred_batch`` interface, with bucket-padded static shapes (TPU adaptation).
Docker process isolation becomes *compilation isolation*: each container
owns its executable and device buffers (DESIGN.md §2).

``service_time`` is pluggable: ``measured`` wall-clock (real execution) or a
calibrated latency model (cluster-scale benches + straggler injection —
paper Figs 6 & 9). ``ReplicaSet`` scales a container across replicas, each
with its *own* adaptive batching queue (paper §4.4.1)."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.batching import AIMDController, BatchQueue, bucket


LatencyModel = Callable[[int], float]    # batch_size -> service seconds


def linear_latency(base: float, per_item: float,
                   jitter: float = 0.0, p_straggle: float = 0.0,
                   straggle_factor: float = 10.0,
                   rng: Optional[np.random.Generator] = None) -> LatencyModel:
    """The paper's empirically-observed linear latency profile (Fig 3), with
    optional straggler injection for §5.2.2 experiments."""
    rng = rng or np.random.default_rng(0)

    def model(n: int) -> float:
        t = base + per_item * n
        if jitter:
            t *= float(1.0 + rng.normal(0, jitter))
        if p_straggle and rng.random() < p_straggle:
            t *= straggle_factor
        return max(t, 1e-6)

    return model


@dataclass
class ContainerStats:
    batches: int = 0
    queries: int = 0
    busy_time: float = 0.0
    failures: int = 0


class JaxModelContainer:
    """Uniform batch-prediction container around a jitted callable.

    predict_fn: np.ndarray [B, ...] -> np.ndarray [B, ...]; inputs are padded
    to the bucket ladder so XLA compiles one executable per bucket."""

    def __init__(self, model_id: str, predict_fn: Callable,
                 *, latency_model: Optional[LatencyModel] = None,
                 bucket_cap: int = 4096, fail: bool = False):
        self.model_id = model_id
        self._fn = predict_fn
        self.latency_model = latency_model
        self.bucket_cap = bucket_cap
        self.stats = ContainerStats()
        self.fail = fail            # health: failed containers are skipped

    def pred_batch(self, inputs: Sequence[Any]) -> List[Any]:
        ys, _ = self.pred_batch_timed(inputs)
        return ys

    def pred_batch_timed(self, inputs: Sequence[Any]):
        """Returns (outputs, service_time). service_time is measured when no
        latency model is installed, modeled otherwise."""
        n = len(inputs)
        x = np.stack([np.asarray(v) for v in inputs])
        nb = bucket(n, cap=self.bucket_cap)
        if nb != n:
            pad = np.repeat(x[-1:], nb - n, axis=0)
            x = np.concatenate([x, pad], axis=0)
        t0 = time.perf_counter()
        y = np.asarray(self._fn(x))
        measured = time.perf_counter() - t0
        service = (self.latency_model(n) if self.latency_model is not None
                   else measured)
        self.stats.batches += 1
        self.stats.queries += n
        self.stats.busy_time += service
        return [y[i] for i in range(n)], service


class ReplicaSet:
    """Container replicas with per-replica adaptive batching (paper §4.4.1).

    Replicas may have heterogeneous performance (different latency models);
    dispatch picks the earliest-free replica."""

    def __init__(self, replicas: Sequence[JaxModelContainer],
                 make_controller: Callable[[], AIMDController],
                 batch_delay: float = 0.0):
        assert replicas
        self.model_id = replicas[0].model_id
        self.replicas = list(replicas)
        self.queues = [BatchQueue(make_controller(), batch_delay)
                       for _ in replicas]
        self.free_at = [0.0 for _ in replicas]

    def attach_metrics(self, metrics) -> None:
        """Point every queue (current or replaced) at a shared registry —
        call this again after swapping queues so per-model telemetry
        survives reconstruction."""
        for queue in self.queues:
            queue.metrics = metrics
            queue.model_id = self.model_id

    def healthy(self) -> List[int]:
        return [i for i, r in enumerate(self.replicas) if not r.fail]

    def pick(self, now: float) -> Optional[int]:
        h = self.healthy()
        if not h:
            return None
        return min(h, key=lambda i: max(self.free_at[i], now))
