"""Model containers (paper §4.4).

``JaxModelContainer`` wraps any jitted predict function behind the uniform
``pred_batch`` interface, with bucket-padded static shapes (TPU adaptation).
Docker process isolation becomes *compilation isolation*: each container
owns its executable and device buffers (DESIGN.md §2).

``service_time`` is pluggable: ``measured`` wall-clock (real execution) or a
calibrated latency model (cluster-scale benches + straggler injection —
paper Figs 6 & 9). ``ReplicaSet`` scales a container across replicas, each
with its *own* adaptive batching queue (paper §4.4.1)."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.batching import AIMDController, BatchQueue, bucket


LatencyModel = Callable[[int], float]    # batch_size -> service seconds


class ContainerFault(RuntimeError):
    """A dispatched batch did not produce predictions (DESIGN.md §14)."""


class ContainerCrashed(ContainerFault):
    """The replica process is down: the batch is *silently lost* — no error
    response ever comes back, only a missed completion a failure detector
    can notice."""


class TransientError(ContainerFault):
    """The replica answered the batch with an error (fail-fast): the work is
    lost but the caller learns immediately and may retry."""

# Default-stream spawner for latency models constructed without an explicit
# rng: every call takes its own child of this seed sequence, so two
# independently-constructed containers draw *independent* jitter/straggler
# streams (with a shared default_rng(0) they straggled in lockstep).
# Construction order is deterministic, so runs stay reproducible.
_DEFAULT_LATENCY_SEEDS = np.random.SeedSequence(0)


def linear_latency(base: float, per_item: float,
                   jitter: float = 0.0, p_straggle: float = 0.0,
                   straggle_factor: float = 10.0,
                   rng: Optional[np.random.Generator] = None) -> LatencyModel:
    """The paper's empirically-observed linear latency profile (Fig 3), with
    optional straggler injection for §5.2.2 experiments."""
    if rng is None:
        rng = np.random.default_rng(_DEFAULT_LATENCY_SEEDS.spawn(1)[0])

    def model(n: int) -> float:
        t = base + per_item * n
        if jitter:
            t *= float(1.0 + rng.normal(0, jitter))
        if p_straggle and rng.random() < p_straggle:
            t *= straggle_factor
        return max(t, 1e-6)

    return model


@dataclass
class ContainerStats:
    batches: int = 0
    queries: int = 0
    busy_time: float = 0.0
    failures: int = 0


class JaxModelContainer:
    """Uniform batch-prediction container around a jitted callable.

    predict_fn: np.ndarray [B, ...] -> np.ndarray [B, ...]; inputs are padded
    to the bucket ladder so XLA compiles one executable per bucket."""

    def __init__(self, model_id: str, predict_fn: Callable,
                 *, latency_model: Optional[LatencyModel] = None,
                 bucket_cap: int = 4096, fail: bool = False):
        self.model_id = model_id
        self._fn = predict_fn
        self.latency_model = latency_model
        self.bucket_cap = bucket_cap
        self.stats = ContainerStats()
        self.fail = fail            # health: failed containers are skipped
        self.faults = None          # Optional[ReplicaFaults] — DESIGN.md §14

    def pred_batch(self, inputs: Sequence[Any]) -> List[Any]:
        ys, _ = self.pred_batch_timed(inputs)
        return ys

    def pred_batch_timed(self, inputs: Sequence[Any],
                         now: Optional[float] = None):
        """Returns (outputs, service_time). service_time is measured when no
        latency model is installed, modeled otherwise.

        With a fault model attached (``self.faults``) and a dispatch time,
        the batch is subject to injected failures: ``ContainerCrashed`` when
        the replica is down at dispatch or crashes mid-service (the batch is
        silently lost), ``TransientError`` on a seeded per-batch error roll
        (fail-fast), and latency-degradation multipliers on the modeled
        service time. Every raised fault increments ``stats.failures``."""
        if self.faults is not None and now is not None:
            try:
                self.faults.check_dispatch(now)
            except ContainerFault:
                self.stats.failures += 1
                raise
        n = len(inputs)
        x = np.stack([np.asarray(v) for v in inputs])
        nb = bucket(n, cap=self.bucket_cap)
        if nb != n:
            pad = np.repeat(x[-1:], nb - n, axis=0)
            x = np.concatenate([x, pad], axis=0)
        t0 = time.perf_counter()
        y = np.asarray(self._fn(x))
        measured = time.perf_counter() - t0
        service = (self.latency_model(n) if self.latency_model is not None
                   else measured)
        if self.faults is not None and now is not None:
            service *= self.faults.multiplier(now)
            try:
                self.faults.check_service(now, service)
            except ContainerFault:
                self.stats.failures += 1
                raise
        self.stats.batches += 1
        self.stats.queries += n
        self.stats.busy_time += service
        return [y[i] for i in range(n)], service


class ReplicaSet:
    """Container replicas with per-replica adaptive batching (paper §4.4.1).

    Replicas may have heterogeneous performance (different latency models);
    dispatch picks the earliest-free replica.

    The set is *dynamic* (control plane, DESIGN.md §10): ``add_replica``
    grows capacity mid-run and ``retire_replica`` shrinks it gracefully —
    the retiring replica's backlog is requeued to a live replica and its
    in-flight batch finishes before the slot is reaped. Slots are never
    reused, so replica indices held by in-flight completion events stay
    valid for the whole run."""

    def __init__(self, replicas: Sequence[JaxModelContainer],
                 make_controller: Callable[[], AIMDController],
                 batch_delay: float = 0.0):
        assert replicas
        self.model_id = replicas[0].model_id
        self.replicas = list(replicas)
        self._make_controller = make_controller
        self._batch_delay = batch_delay
        self._metrics = None
        self._tracer = None
        self.queues = [BatchQueue(make_controller(), batch_delay)
                       for _ in replicas]
        self.free_at = [0.0 for _ in replicas]
        self.draining = [False for _ in replicas]
        self.retired = [False for _ in replicas]
        # failure detection / recovery state (DESIGN.md §14): replica
        # indices the frontend's detector has marked unhealthy (fail=True)
        # and may later clear via probe_recovered. has_faults flags that a
        # fault plan is attached so hot paths can skip fault handling
        # entirely when the set is guaranteed healthy.
        self.suspected: set = set()
        self.has_faults = False

    def attach_metrics(self, metrics) -> None:
        """Point every queue (current or replaced) at a shared registry —
        call this again after swapping queues so per-model telemetry
        survives reconstruction."""
        self._metrics = metrics
        for queue in self.queues:
            queue.metrics = metrics
            queue.model_id = self.model_id

    def attach_tracer(self, tracer) -> None:
        """Point every queue (current or future) at a shared span tracer
        (repro.obs) — the same contract as ``attach_metrics``."""
        self._tracer = tracer
        for queue in self.queues:
            queue.tracer = tracer

    def healthy(self) -> List[int]:
        return [i for i, r in enumerate(self.replicas)
                if not r.fail and not self.retired[i]]

    def routable(self) -> List[int]:
        """Replicas eligible for *new* work: healthy and not draining."""
        return [i for i in self.healthy() if not self.draining[i]]

    def candidates(self) -> List[int]:
        """The one enqueue-eligibility chain routing shares: routable
        replicas, else merely healthy (everything draining), else every
        slot (everything failed — keep accepting work so recovery can
        drain it)."""
        return (self.routable() or self.healthy()
                or list(range(len(self.queues))))

    @property
    def n_live(self) -> int:
        return len(self.routable())

    # -- dynamic capacity (control plane) -------------------------------
    def add_replica(self, container: JaxModelContainer,
                    now: float = 0.0) -> int:
        """Grow capacity with a fresh replica (own queue + controller);
        returns its index. Telemetry attaches automatically when a registry
        was installed."""
        assert container.model_id == self.model_id
        queue = BatchQueue(self._make_controller(), self._batch_delay)
        if self._metrics is not None:
            queue.metrics = self._metrics
            queue.model_id = self.model_id
        if self._tracer is not None:
            queue.tracer = self._tracer
        self.replicas.append(container)
        self.queues.append(queue)
        self.free_at.append(float(now))
        self.draining.append(False)
        self.retired.append(False)
        return len(self.replicas) - 1

    def retire_replica(self, ri: int, now: float = 0.0) -> None:
        """Begin a graceful drain: the replica stops receiving new work,
        its queued backlog moves to the least-loaded live replica, and its
        in-flight batch (if any) runs to completion before ``reap``
        finalizes the slot."""
        if self.retired[ri] or self.draining[ri]:
            return
        targets = [i for i in self.routable() if i != ri]
        if not targets:
            raise ValueError("cannot retire the last live replica")
        self.draining[ri] = True
        tgt = min(targets, key=lambda i: (len(self.queues[i]), i))
        self.queues[ri].requeue_to(self.queues[tgt])
        self.reap(now)

    def reap(self, now: float) -> None:
        """Finalize draining replicas whose in-flight work has completed."""
        for i in range(len(self.replicas)):
            if (self.draining[i] and not self.retired[i]
                    and not self.queues[i] and self.free_at[i] <= now):
                self.draining[i] = False
                self.retired[i] = True

    # -- fault injection + recovery (DESIGN.md §14) ---------------------
    def set_faults(self, ri: int, faults) -> None:
        """Install a per-replica fault model (``repro.faults.ReplicaFaults``)
        on an existing replica slot."""
        self.replicas[ri].faults = faults
        self.has_faults = True

    def probe_recovered(self, now: float) -> List[int]:
        """Health-probe detector-suspected replicas; clear the ``fail`` mark
        on any whose fault window has passed and return the rejoined
        indices. Only detector-marked replicas are probed — a static
        ``fail=True`` the harness set by hand is never overridden."""
        rejoined = []
        for ri in sorted(self.suspected):
            if self.retired[ri]:
                self.suspected.discard(ri)
                continue
            f = self.replicas[ri].faults
            if f is None or not f.crashed(now):
                self.replicas[ri].fail = False
                self.suspected.discard(ri)
                # the replica restarts idle: stale busy-until estimates from
                # before the crash must not keep repelling (or attracting)
                # traffic
                self.free_at[ri] = float(now)
                rejoined.append(ri)
        return rejoined

    def est_service(self, ri: int, default: float = 0.0) -> float:
        """Observed mean service seconds per query for one replica (its
        cumulative busy time over queries served) — the per-replica stat
        heterogeneity-aware routing and the autoscaler's queueing model
        consume."""
        st = self.replicas[ri].stats
        return st.busy_time / st.queries if st.queries else default

    def expected_completion(self, ri: int, now: float,
                            default: float = 0.0) -> float:
        """Expected time from ``now`` until a query enqueued on replica
        ``ri`` would finish: residual busy time plus the backlog (and the
        query itself) at the observed per-query service estimate. The one
        ECT formula both the router and admission control consume."""
        wait = max(self.free_at[ri] - now, 0.0)
        est = self.est_service(ri, default)
        return wait + (len(self.queues[ri]) + 1) * est

    def mean_service(self, default: float = 0.0) -> float:
        """Set-wide mean service seconds per query across every replica."""
        busy = sum(r.stats.busy_time for r in self.replicas)
        queries = sum(r.stats.queries for r in self.replicas)
        return busy / queries if queries else default

    def replica_stats(self) -> List[Dict[str, Any]]:
        """Per-replica accounting snapshot (control-plane introspection)."""
        return [{
            "replica": i,
            "batches": r.stats.batches,
            "queries": r.stats.queries,
            "busy_time": r.stats.busy_time,
            "queued": len(self.queues[i]),
            "draining": self.draining[i],
            "retired": self.retired[i],
            "failures": r.stats.failures,
            "failed": r.fail,
        } for i, r in enumerate(self.replicas)]
