"""Adaptive batching (paper §4.3).

* ``AIMDController`` — additive-increase / multiplicative-decrease search for
  the largest batch size whose evaluation latency stays under the SLO
  (paper §4.3.1; small 10% backoff because the optimum is stable).
* ``QuantileRegressionController`` — the alternative the paper compares
  against: estimate P99 latency as a linear function of batch size via
  pinball-loss regression, invert for the SLO.
* ``BatchQueue`` — per-container queue with *delayed batching* (paper
  §4.3.2, Nagle-style) and max-batch admission.
* ``bucket`` — TPU adaptation (DESIGN.md §2): XLA needs static shapes, so
  dispatched batches are padded up a geometric bucket ladder; AIMD adapts
  admission while buckets bound recompilation.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import metrics as M
from repro.core.interfaces import Query


# ---------------------------------------------------------------------------
# batch-size controllers
# ---------------------------------------------------------------------------

class AIMDController:
    """Additive-increase (+``additive``) until the SLO is exceeded, then a
    multiplicative backoff (x``backoff``). The paper uses a small backoff
    (10%) because the optimal batch size does not fluctuate much."""

    def __init__(self, slo: float, *, additive: int = 2, backoff: float = 0.9,
                 init: int = 1, max_batch: int = 4096):
        assert 0 < backoff < 1 and additive >= 1
        self.slo = slo
        self.additive = additive
        self.backoff = backoff
        self.cap = max_batch
        self._max = float(init)

    @property
    def max_batch_size(self) -> int:
        return max(1, int(self._max))

    def record(self, batch_size: int, latency: float) -> None:
        if batch_size < self.max_batch_size:
            return        # under-full batch: not informative about the limit
        if latency > self.slo:
            self._max = max(1.0, self._max * self.backoff)
        else:
            self._max = min(float(self.cap), self._max + self.additive)


class QuantileRegressionController:
    """Estimate latency_q(batch) ≈ a*batch + b at quantile ``q``, then set
    max_batch = (slo - b) / a.

    The latency profile is strongly linear (paper Fig 3), so the slope comes
    from ordinary least squares and the intercept from the empirical
    q-quantile of the residuals — a deterministic, scale-free estimator
    (pinball SGD at q=0.99 converges pathologically slowly). Exploration:
    until the window covers >= 2 distinct batch sizes, the bound grows
    additively like AIMD so the regression has signal to fit."""

    def __init__(self, slo: float, *, q: float = 0.99, window: int = 512,
                 max_batch: int = 4096, refit_every: int = 16):
        self.slo = slo
        self.q = q
        self.window: Deque[Tuple[int, float]] = deque(maxlen=window)
        self.cap = max_batch
        self.refit_every = refit_every
        self._n = 0
        self._a, self._b = 0.0, 0.0
        self._max = 1

    @property
    def max_batch_size(self) -> int:
        return self._max

    def record(self, batch_size: int, latency: float) -> None:
        self.window.append((batch_size, latency))
        self._n += 1
        # explore upward only until the regression has signal to fit
        if (self._a == 0.0 and latency <= self.slo
                and batch_size >= self._max):
            self._max = min(self.cap, self._max + 1)
        if self._n % self.refit_every == 0 and len(self.window) >= 8:
            self._fit()

    def _fit(self) -> None:
        data = np.asarray(self.window, dtype=np.float64)
        x, y = data[:, 0], data[:, 1]
        if np.ptp(x) < 1e-9:
            return                      # no batch-size variation yet
        a = float(np.cov(x, y, bias=True)[0, 1] / np.var(x))
        b = float(np.quantile(y - a * x, self.q))
        self._a, self._b = a, b
        if a <= 1e-12:
            self._max = self.cap
        else:
            self._max = int(np.clip((self.slo - b) / a, 1, self.cap))


class FixedController:
    """No adaptivity — the paper's 'no batching' / static baseline."""

    def __init__(self, size: int = 1):
        self._max = size

    @property
    def max_batch_size(self) -> int:
        return self._max

    def record(self, batch_size: int, latency: float) -> None:
        pass


# ---------------------------------------------------------------------------
# bucketed static shapes (TPU adaptation)
# ---------------------------------------------------------------------------

def bucket(n: int, *, ladder: Sequence[int] = (), cap: int = 4096) -> int:
    """Smallest ladder size >= n (default: powers of two up to cap; above
    the cap the exact size is returned — no padding, no recompile guard).

    The same function pads both dispatched *batch sizes* and — via an
    explicit ``ladder`` from :func:`prompt_length_ladder` — prompt
    *lengths*, so distinct compiled prefill shapes are bounded by
    ``len(batch rungs) * len(length rungs)`` instead of by the number of
    distinct (count, length) pairs in the workload."""
    if ladder:
        for b in ladder:
            if b >= n:
                return b
        return max(ladder[-1], n)
    b = 1
    while b < n and b < cap:
        b <<= 1
    return max(b, n) if n > cap else b


def prompt_length_ladder(cap: int, *, lo: int = 8,
                         factor: float = 2.0) -> Tuple[int, ...]:
    """Geometric prompt-length rungs ``lo, lo*factor, ...`` capped at
    ``cap`` (the cap itself is always the last rung, so every prompt that
    fits the cap pads to a rung). ``len(result)`` bounds the number of
    distinct prefill sequence lengths the engine can compile."""
    assert cap >= 1 and lo >= 1 and factor > 1.0
    rungs: List[int] = []
    v = min(lo, cap)
    while v < cap:
        rungs.append(int(v))
        v = max(int(v) + 1, int(math.ceil(v * factor)))
    rungs.append(int(cap))
    return tuple(rungs)


# ---------------------------------------------------------------------------
# per-container queue with delayed batching
# ---------------------------------------------------------------------------

@dataclass
class BatchQueue:
    """Adaptive batching queue for one model container (paper §4.3).

    ``batch_delay``: under moderate load, hold dispatch up to this long after
    the oldest enqueued query so more queries can join (paper §4.3.2).

    ``metrics`` / ``model_id``: when attached (frontend does this at
    construction), every dispatch reports queue depth, batch size, and
    per-model service time through the shared telemetry schema.

    ``tracer``: when attached (repro.obs), every dispatch additionally
    emits a global ``batch.dispatch`` trace event — the batch boundaries a
    flamegraph needs to explain queue-wait spans."""

    controller: AIMDController
    batch_delay: float = 0.0
    _q: Deque[Query] = field(default_factory=deque)
    metrics: Optional[object] = None
    model_id: Optional[str] = None
    tracer: Optional[object] = None

    def put(self, query: Query) -> None:
        self._q.append(query)

    def requeue_to(self, other: "BatchQueue",
                   keep: Optional[Callable[[Query], bool]] = None) -> int:
        """Hand every queued query to another queue, merge-ordered by
        arrival time (drain support: a retiring replica gives its backlog to
        a live one without dropping or reordering work). Returns the number
        of queries moved.

        ``keep`` filters the drain (failure recovery, DESIGN.md §14): only
        queries it accepts move; the rest — already finalized or shed, so
        recomputing them is pure waste — are dropped with the dead
        replica."""
        if other is self:
            return 0
        mine = list(self._q) if keep is None else \
            [q for q in self._q if keep(q)]
        moved = len(mine)
        if moved:
            merged = sorted(list(other._q) + mine,
                            key=lambda q: (q.arrival_time, q.query_id))
            other._q.clear()
            other._q.extend(merged)
        self._q.clear()
        return moved

    def __len__(self) -> int:
        return len(self._q)

    def oldest_arrival(self) -> Optional[float]:
        return self._q[0].arrival_time if self._q else None

    def ready(self, now: float) -> bool:
        if not self._q:
            return False
        if len(self._q) >= self.controller.max_batch_size:
            return True
        return (now - self._q[0].arrival_time) >= self.batch_delay

    def next_batch(self, now: float) -> List[Query]:
        """Dequeue up to the controller's current max batch size."""
        depth = len(self._q)
        n = min(depth, self.controller.max_batch_size)
        batch = [self._q.popleft() for _ in range(n)]
        if self.tracer is not None and batch:
            self.tracer.global_event(
                "dispatch", "frontend.batch", now,
                attrs={"model": self.model_id, "size": n, "depth": depth})
        if self.metrics is not None and batch:
            self.metrics.observe(M.QUEUE_DEPTH, depth)
            if self.model_id is not None:
                self.metrics.observe_both(M.BATCH_SIZE, n, model=self.model_id)
                self.metrics.inc_both(M.BATCHES, model=self.model_id)
                self.metrics.inc(M.QUERIES_SUBMITTED, n, model=self.model_id)
            else:
                self.metrics.observe(M.BATCH_SIZE, n)
                self.metrics.inc(M.BATCHES)
        return batch

    def record(self, batch_size: int, latency: float) -> None:
        self.controller.record(batch_size, latency)
        if self.metrics is not None:
            if self.model_id is not None:
                self.metrics.observe_both(M.SERVICE, latency,
                                          model=self.model_id)
            else:
                self.metrics.observe(M.SERVICE, latency)
