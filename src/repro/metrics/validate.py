"""Schema validation for the repro observability documents (DESIGN.md
§9/§13/§15): ``repro.metrics/v1`` reports, ``repro.trace/v1`` span logs,
``repro.timeseries/v1`` fleet telemetry, and ``repro.audit/v1`` decision
audit logs.

    PYTHONPATH=src python -m repro.metrics.validate report.json [ts.json ...]
    PYTHONPATH=src python -m repro.metrics.validate --strict trace.json

Each file is dispatched on its ``schema`` field. Validation is hand-rolled
(no jsonschema dependency): structural checks on the canonical key sets and
value types, plus the semantic invariants the schemas promise —

* histogram summaries are schema-stable (full key set, nulls when empty);
* ``throughput_qps`` is ``null`` exactly when the marked span is degenerate
  (zero duration), never a fabricated 0-division value;
* ``latency_attribution`` fractions sum to 1 ± 1e-6 when any query was
  attributed;
* spans are well-formed intervals (``end >= start``), events are instants,
  and child spans nest within their parent's bounds;
* time-series points are time-ordered ``[t, value]`` pairs and alert events
  are well-formed fire/resolve transitions;
* audit records carry monotonically increasing ``seq`` numbers and the
  per-action counts tally up to ``total``.

Separately from hard errors, ``document_warnings`` flags *truncation*: a
span log, series ring, or audit log that dropped records due to bounded
capacity. Warnings print but pass by default; ``--strict`` promotes them to
failures (nonzero exit) for CI jobs that must see complete artifacts.

``validate_*`` return a list of human-readable errors (empty = valid); the
CLI exits nonzero if any file fails.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

from repro.core.metrics import SCHEMA as METRICS_SCHEMA
from repro.obs.audit import ACTIONS, AUDIT_SCHEMA
from repro.obs.timeseries import TIMESERIES_SCHEMA
from repro.obs.tracer import TRACE_SCHEMA

_HIST_KEYS = {"count", "sum", "mean", "min", "max", "p50", "p95", "p99"}
_REPORT_KEYS = {"schema", "stack", "duration_s", "queries", "throughput_qps",
                "latency_s", "slo", "admission", "cache", "batch_size",
                "queue_depth", "stragglers", "faults", "per_model"}
_FAULT_KEYS = {"crashes", "transient_errors", "slow_batches", "failures",
               "detected", "recovered", "requeued_queries", "retries",
               "retry_exhausted", "hedges", "hedge_wins"}
_SPAN_KEYS = {"span_id", "trace_id", "parent_id", "name", "component",
              "start", "end", "kind", "budget_s", "attrs"}
_ATTRIBUTION_EPS = 1e-6


def _num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _check_hist(errs: List[str], h: Any, path: str) -> None:
    if not isinstance(h, dict):
        errs.append(f"{path}: histogram summary must be an object")
        return
    missing = _HIST_KEYS - set(h)
    if missing:
        errs.append(f"{path}: missing histogram keys {sorted(missing)}")
        return
    if not isinstance(h["count"], int) or h["count"] < 0:
        errs.append(f"{path}.count: must be a non-negative int")
        return
    stats = [k for k in _HIST_KEYS if k != "count"]
    if h["count"] == 0:
        bad = [k for k in stats if h[k] is not None]
        if bad:
            errs.append(f"{path}: empty histogram must have null stats, "
                        f"got values for {sorted(bad)}")
    else:
        bad = [k for k in stats if not _num(h[k])]
        if bad:
            errs.append(f"{path}: non-numeric stats {sorted(bad)} "
                        f"with count > 0")


def validate_report(doc: Dict[str, Any]) -> List[str]:
    """Validate a ``repro.metrics/v1`` report; returns errors (empty=ok)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["report: not a JSON object"]
    if doc.get("schema") != METRICS_SCHEMA:
        return [f"schema: expected {METRICS_SCHEMA!r}, "
                f"got {doc.get('schema')!r}"]
    missing = _REPORT_KEYS - set(doc)
    if missing:
        errs.append(f"report: missing keys {sorted(missing)}")
        return errs
    if not isinstance(doc["stack"], str):
        errs.append("stack: must be a string")
    dur = doc["duration_s"]
    if not _num(dur) or dur < 0:
        errs.append("duration_s: must be a non-negative number")
        dur = None
    q = doc["queries"]
    if (not isinstance(q, dict)
            or not all(isinstance(q.get(k), int)
                       for k in ("submitted", "completed"))):
        errs.append("queries: must carry int submitted/completed")
    thr = doc["throughput_qps"]
    if dur is not None:
        if dur == 0:
            if thr is not None:
                errs.append("throughput_qps: must be null when the marked "
                            f"span is degenerate (duration 0), got {thr!r}")
        elif not _num(thr) or thr < 0:
            errs.append("throughput_qps: must be a non-negative number "
                        f"when duration > 0, got {thr!r}")
    for name in ("latency_s", "batch_size", "queue_depth"):
        _check_hist(errs, doc[name], name)
    slo = doc["slo"]
    if (not isinstance(slo, dict)
            or {"target_s", "violations", "rate", "attainment"} - set(slo)):
        errs.append("slo: must carry target_s/violations/rate/attainment")
    adm = doc["admission"]
    if (not isinstance(adm, dict)
            or {"shed", "degraded", "shed_rate"} - set(adm)):
        errs.append("admission: must carry shed/degraded/shed_rate")
    cache = doc["cache"]
    if (not isinstance(cache, dict)
            or {"hits", "misses", "hit_rate"} - set(cache)):
        errs.append("cache: must carry hits/misses/hit_rate")
    faults = doc["faults"]
    if not isinstance(faults, dict) or _FAULT_KEYS - set(faults):
        errs.append("faults: must carry "
                    f"{'/'.join(sorted(_FAULT_KEYS))}")
    else:
        bad = [k for k in sorted(_FAULT_KEYS)
               if not isinstance(faults[k], int) or faults[k] < 0]
        if bad:
            errs.append(f"faults: non-negative int required for {bad}")
    pm = doc["per_model"]
    if not isinstance(pm, dict):
        errs.append("per_model: must be an object")
    else:
        for m, row in pm.items():
            if not isinstance(row, dict):
                errs.append(f"per_model[{m}]: must be an object")
                continue
            for name in ("latency_s", "service_s", "batch_size"):
                if name in row:
                    _check_hist(errs, row[name], f"per_model[{m}].{name}")
    if "latency_attribution" in doc:
        errs.extend(_check_attribution(doc["latency_attribution"],
                                       "latency_attribution"))
    if "engine" in doc and not isinstance(doc["engine"], dict):
        errs.append("engine: must be an object")
    return errs


def _check_attribution(att: Any, path: str) -> List[str]:
    errs: List[str] = []
    if not isinstance(att, dict) or {"queries", "total_latency_s",
                                     "components"} - set(att):
        return [f"{path}: must carry queries/total_latency_s/components"]
    comps = att["components"]
    if not isinstance(comps, dict):
        return [f"{path}.components: must be an object"]
    fracs = []
    for name, row in comps.items():
        if not isinstance(row, dict) or {"seconds", "fraction"} - set(row):
            errs.append(f"{path}.components[{name}]: must carry "
                        "seconds/fraction")
            continue
        if not _num(row["seconds"]) or not _num(row["fraction"]):
            errs.append(f"{path}.components[{name}]: non-numeric")
            continue
        fracs.append(row["fraction"])
    if not errs and att["queries"] and comps:
        s = sum(fracs)
        if abs(s - 1.0) > _ATTRIBUTION_EPS:
            errs.append(f"{path}: fractions sum to {s!r}, expected 1.0 "
                        f"± {_ATTRIBUTION_EPS}")
    return errs


def validate_trace(doc: Dict[str, Any]) -> List[str]:
    """Validate a ``repro.trace/v1`` span log; returns errors (empty=ok)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["trace: not a JSON object"]
    if doc.get("schema") != TRACE_SCHEMA:
        return [f"schema: expected {TRACE_SCHEMA!r}, "
                f"got {doc.get('schema')!r}"]
    for key in ("sample_rate", "seed", "traces", "sampled_traces", "spans",
                "dropped", "capacity", "attribution"):
        if key not in doc:
            errs.append(f"trace: missing key {key!r}")
    spans = doc.get("spans")
    if not isinstance(spans, list):
        errs.append("spans: must be a list")
        return errs
    if isinstance(doc.get("attribution"), dict):
        errs.extend(_check_attribution(doc["attribution"], "attribution"))
    by_id: Dict[int, Dict[str, Any]] = {}
    for i, s in enumerate(spans):
        if not isinstance(s, dict) or _SPAN_KEYS - set(s):
            errs.append(f"spans[{i}]: missing keys "
                        f"{sorted(_SPAN_KEYS - set(s or {}))}")
            continue
        if not _num(s["start"]):
            errs.append(f"spans[{i}]: non-numeric start")
            continue
        if s["end"] is None or not _num(s["end"]):
            errs.append(f"spans[{i}] ({s['name']}): logged span must have "
                        "a numeric end")
            continue
        if s["end"] < s["start"]:
            errs.append(f"spans[{i}] ({s['name']}): end {s['end']!r} < "
                        f"start {s['start']!r}")
        if s["kind"] == "event" and s["end"] != s["start"]:
            errs.append(f"spans[{i}] ({s['name']}): event must be an "
                        "instant (end == start)")
        by_id[s["span_id"]] = s
    # nesting: a child must lie within its parent's bounds (the parent may
    # have been dropped from the ring — only check when it's present)
    for s in spans:
        if not isinstance(s, dict):
            continue
        parent = by_id.get(s.get("parent_id"))
        if parent is None or parent.get("end") is None:
            continue
        if (s["start"] < parent["start"] - _ATTRIBUTION_EPS
                or s["end"] > parent["end"] + _ATTRIBUTION_EPS):
            errs.append(
                f"span {s['span_id']} ({s['name']}): "
                f"[{s['start']}, {s['end']}] outside parent "
                f"{parent['span_id']} [{parent['start']}, {parent['end']}]")
    return errs


def validate_timeseries(doc: Dict[str, Any]) -> List[str]:
    """Validate a ``repro.timeseries/v1`` document; returns errors."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["timeseries: not a JSON object"]
    if doc.get("schema") != TIMESERIES_SCHEMA:
        return [f"schema: expected {TIMESERIES_SCHEMA!r}, "
                f"got {doc.get('schema')!r}"]
    for key in ("interval_s", "capacity", "samples", "series", "events",
                "monitor"):
        if key not in doc:
            errs.append(f"timeseries: missing key {key!r}")
    if not _num(doc.get("interval_s")) or doc.get("interval_s", 0) <= 0:
        errs.append("interval_s: must be a positive number")
    series = doc.get("series")
    if not isinstance(series, dict):
        errs.append("series: must be an object")
        series = {}
    for name, row in series.items():
        if not isinstance(row, dict) or {"points", "total",
                                         "dropped"} - set(row):
            errs.append(f"series[{name}]: must carry points/total/dropped")
            continue
        for k in ("total", "dropped"):
            if not isinstance(row[k], int) or row[k] < 0:
                errs.append(f"series[{name}].{k}: must be a "
                            "non-negative int")
        pts = row["points"]
        if not isinstance(pts, list):
            errs.append(f"series[{name}].points: must be a list")
            continue
        last_t = None
        for i, pt in enumerate(pts):
            if (not isinstance(pt, list) or len(pt) != 2
                    or not _num(pt[0]) or not _num(pt[1])):
                errs.append(f"series[{name}].points[{i}]: must be a "
                            "[t, value] numeric pair")
                break
            if last_t is not None and pt[0] <= last_t:
                errs.append(f"series[{name}].points[{i}]: timestamps must "
                            f"be strictly increasing ({pt[0]!r} after "
                            f"{last_t!r})")
                break
            last_t = pt[0]
        if isinstance(row.get("total"), int) and len(pts) > row["total"]:
            errs.append(f"series[{name}]: {len(pts)} retained points "
                        f"exceed total {row['total']}")
    events = doc.get("events")
    if not isinstance(events, list):
        errs.append("events: must be a list")
        events = []
    active = False
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or {"t", "kind", "alert",
                                        "evidence"} - set(ev):
            errs.append(f"events[{i}]: must carry t/kind/alert/evidence")
            continue
        if ev["kind"] not in ("fire", "resolve"):
            errs.append(f"events[{i}].kind: must be fire|resolve, "
                        f"got {ev['kind']!r}")
            continue
        # multiwindow alerting is a two-state machine: transitions alternate
        if ev["kind"] == "fire":
            if active:
                errs.append(f"events[{i}]: fire while already firing")
            active = True
        else:
            if not active:
                errs.append(f"events[{i}]: resolve without a prior fire")
            active = False
    mon = doc.get("monitor")
    if mon is not None and not isinstance(mon, dict):
        errs.append("monitor: must be an object or null")
    return errs


def validate_audit(doc: Dict[str, Any]) -> List[str]:
    """Validate a ``repro.audit/v1`` document; returns errors."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["audit: not a JSON object"]
    if doc.get("schema") != AUDIT_SCHEMA:
        return [f"schema: expected {AUDIT_SCHEMA!r}, "
                f"got {doc.get('schema')!r}"]
    for key in ("total", "dropped", "capacity", "counts", "records"):
        if key not in doc:
            errs.append(f"audit: missing key {key!r}")
    for k in ("total", "dropped", "capacity"):
        if k in doc and (not isinstance(doc[k], int) or doc[k] < 0):
            errs.append(f"{k}: must be a non-negative int")
    counts = doc.get("counts")
    if not isinstance(counts, dict):
        errs.append("counts: must be an object")
    elif isinstance(doc.get("total"), int):
        tally = sum(v for v in counts.values() if isinstance(v, int))
        if tally != doc["total"]:
            errs.append(f"counts: tally {tally} != total {doc['total']}")
    records = doc.get("records")
    if not isinstance(records, list):
        errs.append("records: must be a list")
        return errs
    last_seq = None
    for i, r in enumerate(records):
        if not isinstance(r, dict) or {"seq", "t", "actor", "action",
                                       "model", "evidence"} - set(r):
            errs.append(f"records[{i}]: must carry "
                        "seq/t/actor/action/model/evidence")
            continue
        if not isinstance(r["seq"], int):
            errs.append(f"records[{i}].seq: must be an int")
            continue
        if last_seq is not None and r["seq"] <= last_seq:
            errs.append(f"records[{i}].seq: must be strictly increasing "
                        f"({r['seq']} after {last_seq})")
        last_seq = r["seq"]
        if not _num(r["t"]):
            errs.append(f"records[{i}].t: must be numeric")
        if not isinstance(r["evidence"], dict):
            errs.append(f"records[{i}].evidence: must be an object")
        known = ACTIONS.get(r["actor"])
        if known is not None and r["action"] not in known:
            errs.append(f"records[{i}]: unknown action {r['action']!r} "
                        f"for actor {r['actor']!r} (have {list(known)})")
    return errs


_VALIDATORS = {
    METRICS_SCHEMA: "validate_report",
    TRACE_SCHEMA: "validate_trace",
    TIMESERIES_SCHEMA: "validate_timeseries",
    AUDIT_SCHEMA: "validate_audit",
}


def validate_document(doc: Dict[str, Any]) -> List[str]:
    """Dispatch on the ``schema`` field."""
    schema = doc.get("schema") if isinstance(doc, dict) else None
    if schema == METRICS_SCHEMA:
        return validate_report(doc)
    if schema == TRACE_SCHEMA:
        return validate_trace(doc)
    if schema == TIMESERIES_SCHEMA:
        return validate_timeseries(doc)
    if schema == AUDIT_SCHEMA:
        return validate_audit(doc)
    return [f"unknown schema {schema!r}; expected one of "
            f"{sorted(_VALIDATORS)}"]


def document_warnings(doc: Dict[str, Any]) -> List[str]:
    """Truncation warnings: valid documents whose bounded buffers dropped
    data (span log ring, series rings, audit ring) — the artifact is
    self-consistent but incomplete. ``--strict`` promotes these to
    failures."""
    warns: List[str] = []
    if not isinstance(doc, dict):
        return warns
    schema = doc.get("schema")
    if schema == TRACE_SCHEMA:
        if isinstance(doc.get("dropped"), int) and doc["dropped"] > 0:
            warns.append(f"trace: {doc['dropped']} spans dropped "
                         "(ring capacity exceeded)")
    elif schema == METRICS_SCHEMA:
        # reports embed the trace summary when tracing was on
        tr = doc.get("trace")
        if (isinstance(tr, dict) and isinstance(tr.get("dropped"), int)
                and tr["dropped"] > 0):
            warns.append(f"trace: {tr['dropped']} spans dropped "
                         "(ring capacity exceeded)")
    elif schema == TIMESERIES_SCHEMA:
        for name, row in sorted((doc.get("series") or {}).items()):
            if isinstance(row, dict) and isinstance(row.get("dropped"), int) \
                    and row["dropped"] > 0:
                warns.append(f"series[{name}]: {row['dropped']} points "
                             "dropped (ring capacity exceeded)")
    elif schema == AUDIT_SCHEMA:
        if isinstance(doc.get("dropped"), int) and doc["dropped"] > 0:
            warns.append(f"audit: {doc['dropped']} records dropped "
                         "(ring capacity exceeded)")
    return warns


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.metrics.validate",
        description="Validate repro observability documents — "
                    "repro.metrics/v1 reports, repro.trace/v1 span logs, "
                    "repro.timeseries/v1 fleet telemetry, repro.audit/v1 "
                    "audit logs (dispatched on the schema field).")
    p.add_argument("files", nargs="+", help="JSON documents to validate")
    p.add_argument("--strict", action="store_true",
                   help="treat truncation warnings (dropped spans / series "
                        "points / audit records) as failures")
    return p


def main(argv=None) -> int:
    parser = build_parser()
    args = parser.parse_args(argv)
    failed = False
    for path in args.files:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}")
            failed = True
            continue
        errs = validate_document(doc)
        warns = document_warnings(doc) if not errs else []
        if errs:
            failed = True
            print(f"FAIL {path}:")
            for e in errs:
                print(f"  - {e}")
        elif warns and args.strict:
            failed = True
            print(f"FAIL {path} (strict):")
            for w in warns:
                print(f"  - warning: {w}")
        else:
            print(f"OK   {path} ({doc.get('schema')})")
            for w in warns:
                print(f"  - warning: {w}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
