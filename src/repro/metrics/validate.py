"""Schema validation for ``repro.metrics/v1`` reports and ``repro.trace/v1``
span logs (DESIGN.md §9/§13).

    PYTHONPATH=src python -m repro.metrics.validate report.json [trace.json ...]

Each file is dispatched on its ``schema`` field. Validation is hand-rolled
(no jsonschema dependency): structural checks on the canonical key sets and
value types, plus the semantic invariants the schemas promise —

* histogram summaries are schema-stable (full key set, nulls when empty);
* ``throughput_qps`` is ``null`` exactly when the marked span is degenerate
  (zero duration), never a fabricated 0-division value;
* ``latency_attribution`` fractions sum to 1 ± 1e-6 when any query was
  attributed;
* spans are well-formed intervals (``end >= start``), events are instants,
  and child spans nest within their parent's bounds.

``validate_report`` / ``validate_trace`` return a list of human-readable
errors (empty = valid); the CLI exits nonzero if any file fails.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

from repro.core.metrics import SCHEMA as METRICS_SCHEMA
from repro.obs.tracer import TRACE_SCHEMA

_HIST_KEYS = {"count", "sum", "mean", "min", "max", "p50", "p95", "p99"}
_REPORT_KEYS = {"schema", "stack", "duration_s", "queries", "throughput_qps",
                "latency_s", "slo", "admission", "cache", "batch_size",
                "queue_depth", "stragglers", "faults", "per_model"}
_FAULT_KEYS = {"crashes", "transient_errors", "slow_batches", "failures",
               "detected", "recovered", "requeued_queries", "retries",
               "retry_exhausted", "hedges", "hedge_wins"}
_SPAN_KEYS = {"span_id", "trace_id", "parent_id", "name", "component",
              "start", "end", "kind", "budget_s", "attrs"}
_ATTRIBUTION_EPS = 1e-6


def _num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _check_hist(errs: List[str], h: Any, path: str) -> None:
    if not isinstance(h, dict):
        errs.append(f"{path}: histogram summary must be an object")
        return
    missing = _HIST_KEYS - set(h)
    if missing:
        errs.append(f"{path}: missing histogram keys {sorted(missing)}")
        return
    if not isinstance(h["count"], int) or h["count"] < 0:
        errs.append(f"{path}.count: must be a non-negative int")
        return
    stats = [k for k in _HIST_KEYS if k != "count"]
    if h["count"] == 0:
        bad = [k for k in stats if h[k] is not None]
        if bad:
            errs.append(f"{path}: empty histogram must have null stats, "
                        f"got values for {sorted(bad)}")
    else:
        bad = [k for k in stats if not _num(h[k])]
        if bad:
            errs.append(f"{path}: non-numeric stats {sorted(bad)} "
                        f"with count > 0")


def validate_report(doc: Dict[str, Any]) -> List[str]:
    """Validate a ``repro.metrics/v1`` report; returns errors (empty=ok)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["report: not a JSON object"]
    if doc.get("schema") != METRICS_SCHEMA:
        return [f"schema: expected {METRICS_SCHEMA!r}, "
                f"got {doc.get('schema')!r}"]
    missing = _REPORT_KEYS - set(doc)
    if missing:
        errs.append(f"report: missing keys {sorted(missing)}")
        return errs
    if not isinstance(doc["stack"], str):
        errs.append("stack: must be a string")
    dur = doc["duration_s"]
    if not _num(dur) or dur < 0:
        errs.append("duration_s: must be a non-negative number")
        dur = None
    q = doc["queries"]
    if (not isinstance(q, dict)
            or not all(isinstance(q.get(k), int)
                       for k in ("submitted", "completed"))):
        errs.append("queries: must carry int submitted/completed")
    thr = doc["throughput_qps"]
    if dur is not None:
        if dur == 0:
            if thr is not None:
                errs.append("throughput_qps: must be null when the marked "
                            f"span is degenerate (duration 0), got {thr!r}")
        elif not _num(thr) or thr < 0:
            errs.append("throughput_qps: must be a non-negative number "
                        f"when duration > 0, got {thr!r}")
    for name in ("latency_s", "batch_size", "queue_depth"):
        _check_hist(errs, doc[name], name)
    slo = doc["slo"]
    if (not isinstance(slo, dict)
            or {"target_s", "violations", "rate", "attainment"} - set(slo)):
        errs.append("slo: must carry target_s/violations/rate/attainment")
    adm = doc["admission"]
    if (not isinstance(adm, dict)
            or {"shed", "degraded", "shed_rate"} - set(adm)):
        errs.append("admission: must carry shed/degraded/shed_rate")
    cache = doc["cache"]
    if (not isinstance(cache, dict)
            or {"hits", "misses", "hit_rate"} - set(cache)):
        errs.append("cache: must carry hits/misses/hit_rate")
    faults = doc["faults"]
    if not isinstance(faults, dict) or _FAULT_KEYS - set(faults):
        errs.append("faults: must carry "
                    f"{'/'.join(sorted(_FAULT_KEYS))}")
    else:
        bad = [k for k in sorted(_FAULT_KEYS)
               if not isinstance(faults[k], int) or faults[k] < 0]
        if bad:
            errs.append(f"faults: non-negative int required for {bad}")
    pm = doc["per_model"]
    if not isinstance(pm, dict):
        errs.append("per_model: must be an object")
    else:
        for m, row in pm.items():
            if not isinstance(row, dict):
                errs.append(f"per_model[{m}]: must be an object")
                continue
            for name in ("latency_s", "service_s", "batch_size"):
                if name in row:
                    _check_hist(errs, row[name], f"per_model[{m}].{name}")
    if "latency_attribution" in doc:
        errs.extend(_check_attribution(doc["latency_attribution"],
                                       "latency_attribution"))
    if "engine" in doc and not isinstance(doc["engine"], dict):
        errs.append("engine: must be an object")
    return errs


def _check_attribution(att: Any, path: str) -> List[str]:
    errs: List[str] = []
    if not isinstance(att, dict) or {"queries", "total_latency_s",
                                     "components"} - set(att):
        return [f"{path}: must carry queries/total_latency_s/components"]
    comps = att["components"]
    if not isinstance(comps, dict):
        return [f"{path}.components: must be an object"]
    fracs = []
    for name, row in comps.items():
        if not isinstance(row, dict) or {"seconds", "fraction"} - set(row):
            errs.append(f"{path}.components[{name}]: must carry "
                        "seconds/fraction")
            continue
        if not _num(row["seconds"]) or not _num(row["fraction"]):
            errs.append(f"{path}.components[{name}]: non-numeric")
            continue
        fracs.append(row["fraction"])
    if not errs and att["queries"] and comps:
        s = sum(fracs)
        if abs(s - 1.0) > _ATTRIBUTION_EPS:
            errs.append(f"{path}: fractions sum to {s!r}, expected 1.0 "
                        f"± {_ATTRIBUTION_EPS}")
    return errs


def validate_trace(doc: Dict[str, Any]) -> List[str]:
    """Validate a ``repro.trace/v1`` span log; returns errors (empty=ok)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["trace: not a JSON object"]
    if doc.get("schema") != TRACE_SCHEMA:
        return [f"schema: expected {TRACE_SCHEMA!r}, "
                f"got {doc.get('schema')!r}"]
    for key in ("sample_rate", "seed", "traces", "sampled_traces", "spans",
                "dropped", "capacity", "attribution"):
        if key not in doc:
            errs.append(f"trace: missing key {key!r}")
    spans = doc.get("spans")
    if not isinstance(spans, list):
        errs.append("spans: must be a list")
        return errs
    if isinstance(doc.get("attribution"), dict):
        errs.extend(_check_attribution(doc["attribution"], "attribution"))
    by_id: Dict[int, Dict[str, Any]] = {}
    for i, s in enumerate(spans):
        if not isinstance(s, dict) or _SPAN_KEYS - set(s):
            errs.append(f"spans[{i}]: missing keys "
                        f"{sorted(_SPAN_KEYS - set(s or {}))}")
            continue
        if not _num(s["start"]):
            errs.append(f"spans[{i}]: non-numeric start")
            continue
        if s["end"] is None or not _num(s["end"]):
            errs.append(f"spans[{i}] ({s['name']}): logged span must have "
                        "a numeric end")
            continue
        if s["end"] < s["start"]:
            errs.append(f"spans[{i}] ({s['name']}): end {s['end']!r} < "
                        f"start {s['start']!r}")
        if s["kind"] == "event" and s["end"] != s["start"]:
            errs.append(f"spans[{i}] ({s['name']}): event must be an "
                        "instant (end == start)")
        by_id[s["span_id"]] = s
    # nesting: a child must lie within its parent's bounds (the parent may
    # have been dropped from the ring — only check when it's present)
    for s in spans:
        if not isinstance(s, dict):
            continue
        parent = by_id.get(s.get("parent_id"))
        if parent is None or parent.get("end") is None:
            continue
        if (s["start"] < parent["start"] - _ATTRIBUTION_EPS
                or s["end"] > parent["end"] + _ATTRIBUTION_EPS):
            errs.append(
                f"span {s['span_id']} ({s['name']}): "
                f"[{s['start']}, {s['end']}] outside parent "
                f"{parent['span_id']} [{parent['start']}, {parent['end']}]")
    return errs


def validate_document(doc: Dict[str, Any]) -> List[str]:
    """Dispatch on the ``schema`` field."""
    schema = doc.get("schema") if isinstance(doc, dict) else None
    if schema == METRICS_SCHEMA:
        return validate_report(doc)
    if schema == TRACE_SCHEMA:
        return validate_trace(doc)
    return [f"unknown schema {schema!r}; expected {METRICS_SCHEMA!r} or "
            f"{TRACE_SCHEMA!r}"]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.metrics.validate",
        description="Validate repro.metrics/v1 reports and repro.trace/v1 "
                    "span logs (dispatched on the schema field).")
    p.add_argument("files", nargs="+", help="JSON documents to validate")
    return p


def main(argv=None) -> int:
    parser = build_parser()
    args = parser.parse_args(argv)
    failed = False
    for path in args.files:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}")
            failed = True
            continue
        errs = validate_document(doc)
        if errs:
            failed = True
            print(f"FAIL {path}:")
            for e in errs:
                print(f"  - {e}")
        else:
            print(f"OK   {path} ({doc.get('schema')})")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
