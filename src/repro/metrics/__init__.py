"""Schema tooling for the shared report formats.

The metrics *implementation* lives in ``repro.core.metrics`` (re-exported
here for convenience); this package adds the validation surface:

    PYTHONPATH=src python -m repro.metrics.validate report.json trace.json

validates ``repro.metrics/v1`` reports and ``repro.trace/v1`` span logs —
the check benches and CI use instead of ad-hoc key asserts.
"""

from repro.core.metrics import (SCHEMA, MetricsRegistry, StreamingHistogram,
                                VirtualClock)

# NOTE: repro.metrics.validate is intentionally NOT imported here — eager
# import would trip runpy's double-import warning under
# ``python -m repro.metrics.validate``. Import it explicitly.

__all__ = ["SCHEMA", "MetricsRegistry", "StreamingHistogram", "VirtualClock"]
