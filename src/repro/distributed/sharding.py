"""Logical-axis sharding: model code annotates tensors with *logical* axis
names; a rules table maps them to mesh axes (MaxText-style). With no active
context (CPU unit tests) annotations are no-ops.

Rules used in production (DESIGN.md §6):
    batch   -> ('pod', 'data')   [or ('data',) single-pod]
    fsdp    -> 'data'            (train param sharding; None at serve)
    heads/kv_heads/ffn/vocab/expert -> 'model'
    embed/seq/state -> None      (replicated dims)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

_state = threading.local()


class ShardingContext:
    def __init__(self, mesh: Mesh, rules: Dict[str, AxisVal]):
        self.mesh = mesh
        self.rules = dict(rules)

    def spec(self, logical_axes: Sequence[Optional[str]]) -> P:
        out = []
        used = set()
        for ax in logical_axes:
            m = self.rules.get(ax) if ax else None
            # a mesh axis may appear at most once in a PartitionSpec
            if m is not None:
                was_tuple = not isinstance(m, str)
                flat = (m,) if isinstance(m, str) else tuple(m)
                flat = tuple(a for a in flat if a not in used and a in self.mesh.axis_names)
                used.update(flat)
                # keep tuple rules as tuples: P(('data',)) != P('data') on
                # older JAX, and rules like batch=('data',) are tuples
                m = (flat or None) if was_tuple else (flat[0] if flat else None)
            out.append(m)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, logical_axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes))


def current() -> Optional[ShardingContext]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Dict[str, AxisVal]):
    prev = getattr(_state, "ctx", None)
    _state.ctx = ShardingContext(mesh, rules)
    try:
        yield _state.ctx
    finally:
        _state.ctx = prev


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate an intermediate with logical axes (no-op without context)."""
    ctx = current()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding(logical_axes))


# ---------------------------------------------------------------------------
# standard rule tables
# ---------------------------------------------------------------------------

def train_rules(multi_pod: bool) -> Dict[str, AxisVal]:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "fsdp": "data",
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "vocab": "model",
        "expert": "model",
        "expert_ffn": None,
        "embed": None,
        "seq": None,
        "state": None,
        "users": batch,
    }


def strip_pod(rules: Dict[str, AxisVal]) -> Dict[str, AxisVal]:
    """Remove the pod axis from batch-like rules — used when the pod dim is
    handled manually by the cross-pod gradient shard_map (train path)."""
    out = dict(rules)
    for k in ("batch", "users"):
        v = out.get(k)
        if isinstance(v, tuple):
            v = tuple(a for a in v if a != "pod")
            out[k] = v if v else None
        elif v == "pod":
            out[k] = None
    return out


def _shard_map_check_kwarg() -> Optional[str]:
    """The replication-check kwarg jax.shard_map accepts (renamed
    check_rep -> check_vma mid-series), or None when only the experimental
    API exists (0.4.x). Probed once via the signature rather than
    try/except, so real TypeErrors from bad specs aren't masked."""
    if not hasattr(jax, "shard_map"):
        return None
    try:
        import inspect
        params = inspect.signature(jax.shard_map).parameters
        return "check_vma" if "check_vma" in params else "check_rep"
    except (TypeError, ValueError):    # unsignaturable wrapper: assume new
        return "check_vma"


_SHARD_MAP_CHECK_KW = _shard_map_check_kwarg()


def compat_shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """jax.shard_map across JAX versions: the top-level API only exists on
    newer JAX; 0.4.x has the experimental one (with check_rep)."""
    if _SHARD_MAP_CHECK_KW is not None:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             **{_SHARD_MAP_CHECK_KW: check})
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check)


def compat_axis_size(name: str):
    """lax.axis_size across JAX versions (absent on 0.4.x, where
    psum(1, name) is the idiom)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def norm_axes(v: AxisVal) -> Tuple[str, ...]:
    if v is None:
        return ()
    return (v,) if isinstance(v, str) else tuple(v)


def serve_rules(multi_pod: bool, shard_experts_2d: bool = False) -> Dict[str, AxisVal]:
    rules = train_rules(multi_pod)
    rules["fsdp"] = None          # weights replicated over data at serve
    if shard_experts_2d:          # kimi-scale MoE: expert d_ff also over data
        rules["expert_ffn"] = "data"
    return rules


def params_shardings(axes_tree, ctx: ShardingContext):
    """Map a tree of logical-axes tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: ctx.sharding(axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x),
    )
