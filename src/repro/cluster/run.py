"""CLI: replay a workload scenario with the control plane active and print
the structured report.

    PYTHONPATH=src python -m repro.cluster.run --scenario flash_crowd
    PYTHONPATH=src python -m repro.cluster.run --scenario flash_crowd \
        --no-autoscale --admission shed --report-out report.json
    PYTHONPATH=src python -m repro.cluster.run --scenario diurnal --seed 7

The report is the shared ``repro.metrics/v1`` schema plus a ``cluster``
section: the plan, per-model replica timelines, scale events, and
per-replica accounting. Output is deterministic: the same plan yields
byte-identical JSON (DESIGN.md §10).
"""

from __future__ import annotations

import argparse
import sys

from repro.cluster.admission import POLICIES
from repro.cluster.plan import ClusterPlan, cluster_scenario, run_plan_json
from repro.cluster.router import ROUTERS
from repro.faults import parse_fault
from repro.obs.cli import add_fleet_args, build_fleet, write_fleet
from repro.workloads.scenario import SCENARIOS


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.cluster.run",
        description="Replay a workload scenario with the SLO-aware control "
                    "plane (autoscaling, admission control, heterogeneous "
                    "routing) and emit a telemetry report.")
    p.add_argument("--scenario", default="flash_crowd",
                   choices=sorted(SCENARIOS),
                   help="named load profile (re-parameterized for the "
                        "control-plane regime; see DESIGN.md §10)")
    p.add_argument("--stack", default="frontend",
                   choices=("frontend", "lmserver", "pipeline"),
                   help="serving stack to drive (autoscaling: frontend and "
                        "pipeline; the pipeline stack provisions each stage "
                        "independently)")
    p.add_argument("--seed", type=int, default=None,
                   help="override the scenario seed")
    p.add_argument("--duration", type=float, default=None,
                   help="override the trace duration (s)")
    p.add_argument("--rate", type=float, default=None,
                   help="override the mean arrival rate (qps)")
    p.add_argument("--replicas", type=int, default=None,
                   help="initial replicas per model")
    p.add_argument("--no-autoscale", dest="autoscale", action="store_false",
                   help="freeze replica counts (fixed-capacity baseline)")
    p.add_argument("--admission", default=None, choices=POLICIES,
                   help="SLO-aware admission policy (default: off)")
    p.add_argument("--router", default="lect", choices=sorted(ROUTERS),
                   help="replica routing strategy")
    p.add_argument("--tick", type=float, default=0.05,
                   help="control period in virtual seconds")
    p.add_argument("--max-replicas", type=int, default=8,
                   help="autoscaler ceiling per model")
    p.add_argument("--fault", action="append", default=[], metavar="SPEC",
                   help="inject a fault (repeatable; DESIGN.md §14): "
                        "crash:<model>:<replica>@<at>[:<recover_at>], "
                        "flaky:<model>:<replica>:<p>, or "
                        "slow:<model>:<replica>:<factor>[@<from>:<until>]")
    p.add_argument("--no-recovery", dest="recovery", action="store_false",
                   help="disable failure detection + hedged retries (the "
                        "collapse baseline; only meaningful with --fault)")
    p.add_argument("--report-out", default=None,
                   help="write the JSON report here instead of stdout")
    p.add_argument("--trace-out", default=None,
                   help="record per-query spans (repro.obs) and write the "
                        "repro.trace/v1 span log here — byte-identical per "
                        "seed; convert with python -m repro.obs.export")
    p.add_argument("--trace-sample-rate", type=float, default=1.0,
                   help="head-based trace sampling rate in [0, 1] "
                        "(default 1.0; only meaningful with --trace-out)")
    add_fleet_args(p)
    return p


def main(argv=None) -> int:
    parser = build_parser()
    args = parser.parse_args(argv)
    overrides = {k: v for k, v in (("seed", args.seed),
                                   ("duration", args.duration),
                                   ("rate", args.rate),
                                   ("replicas", args.replicas))
                 if v is not None}
    if args.stack == "pipeline":
        # the pipeline stack brings its own model zoo + cost shape
        # (repro.pipeline.scenario); the single-model CLUSTER_DEFAULTS
        # would distort it, so use the named scenario as-is
        import dataclasses

        from repro.workloads.scenario import SCENARIOS as _S
        sc = dataclasses.replace(_S[args.scenario], **overrides)
    else:
        sc = cluster_scenario(args.scenario, **overrides)
    if sc.duration <= 0:
        parser.error("--duration must be > 0")
    if sc.rate <= 0:
        parser.error("--rate must be > 0")
    if sc.kind != "poisson" and sc.rate > sc.peak_rate:
        parser.error(f"--rate {sc.rate:g} exceeds the {sc.name!r} scenario's "
                     f"peak rate {sc.peak_rate:g}")
    if sc.replicas < 1:
        parser.error("--replicas must be >= 1")
    if args.tick <= 0:
        parser.error("--tick must be > 0")
    for spec in args.fault:
        try:
            parse_fault(spec)
        except ValueError as e:
            parser.error(str(e))
    if args.fault and args.stack == "lmserver":
        parser.error("--fault applies to the frontend/pipeline stacks")
    plan = ClusterPlan(scenario=sc, stack=args.stack,
                       autoscale=args.autoscale, admission=args.admission,
                       router=args.router, tick=args.tick,
                       max_replicas=args.max_replicas,
                       faults=tuple(args.fault), recovery=args.recovery)
    tracer = None
    if args.trace_out:
        if not 0.0 <= args.trace_sample_rate <= 1.0:
            parser.error("--trace-sample-rate must be in [0, 1]")
        from repro.obs import Tracer
        tracer = Tracer(sample_rate=args.trace_sample_rate, seed=sc.seed)
    sampler, audit = build_fleet(args, parser)
    text = run_plan_json(plan, tracer=tracer, sampler=sampler, audit=audit)
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            f.write(tracer.to_json() + "\n")
    write_fleet(args, sampler, audit)
    if args.report_out:
        with open(args.report_out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
