"""Per-model reactive autoscaling (DESIGN.md §10).

Clipper scales throughput by replicating containers (paper §4.4.1, Fig 6)
but provisions them statically; InferLine's observation is that tight
latency objectives under time-varying load need a controller that
continuously re-provisions. ``Autoscaler`` closes that loop: each control
tick it samples the shared telemetry (routed arrival rate, backlog, mean
service time) and grows or drains the model's ``ReplicaSet``.

Target replica count combines two deterministic signals:

* **queueing model** — keep utilization under a cap:
  ``n_rate = ceil(lambda * E[service] / utilization_cap)`` where ``lambda``
  is the routed-queries rate over the last tick and ``E[service]`` the
  observed mean service seconds per query;
* **backlog drain** — clear the standing queue within ``drain_target``
  seconds (default: the SLO): ``n_backlog = ceil(backlog * E[service] /
  drain_target)``.

Hysteresis is asymmetric, the classic flash-crowd shape: scale **up**
immediately (after ``up_ticks`` consecutive ticks of demand, default 1) by
as many replicas as the target asks; scale **down** only after
``down_ticks`` consecutive low-demand ticks, then one replica per tick, so
a lull inside a burst never collapses capacity. Retired replicas drain
gracefully (``ReplicaSet.retire_replica``) — queued work is requeued, the
in-flight batch finishes.

Everything the controller reads is a pure function of the virtual-clock
run, so an autoscaled scenario remains byte-identical from its seed.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

from repro.core import metrics as M
from repro.core.containers import JaxModelContainer, ReplicaSet
from repro.core.metrics import MetricsRegistry


@dataclass(frozen=True)
class AutoscalerConfig:
    tick: float = 0.05              # control period (virtual seconds)
    utilization_cap: float = 0.7    # rho target for the queueing model
    drain_target: Optional[float] = None   # backlog drain seconds (None=SLO)
    min_replicas: int = 1
    max_replicas: int = 8
    up_ticks: int = 1               # consecutive high ticks before growing
    down_ticks: int = 4             # consecutive low ticks before draining


class Autoscaler:
    """Reactive controller for one model's ReplicaSet.

    ``make_replica(model_id) -> JaxModelContainer`` supplies fresh replicas;
    in calibrated simulation it must seed each new container's latency
    model deterministically (see ``plan.replica_factory``).

    ``slo`` may be a float or a zero-arg callable returning one — the
    pipeline stack passes the model's *stage share* of the pipeline SLO as
    a callable so the drain target follows the planner's live replans."""

    def __init__(self, rs: ReplicaSet,
                 make_replica: Callable[[str], JaxModelContainer],
                 metrics: MetricsRegistry, cfg: AutoscalerConfig, *,
                 slo: float, audit=None):
        assert cfg.min_replicas >= 1
        self.rs = rs
        self.make_replica = make_replica
        self.metrics = metrics
        self.cfg = cfg
        self.slo = slo
        self.audit = audit              # optional repro.obs AuditLog
        self.model_id = rs.model_id
        self._last_routed = metrics.counter(M.QUERIES_ROUTED,
                                            model=self.model_id)
        self._up_streak = 0
        self._down_streak = 0
        self.events: List[Dict[str, Any]] = []     # scale actions, reported
        self.timeline: List[List[float]] = []      # [t, live] per tick
        self.peak_live = rs.n_live

    # ------------------------------------------------------------------
    def desired(self, lam: float) -> int:
        """Deterministic replica target — a pure function of the arrival
        rate ``lam`` (routed qps over the last tick) and the replica set's
        current backlog + service stats."""
        return self._target(lam)[0]

    def _target(self, lam: float) -> tuple:
        """(want, evidence): the replica target plus the decision-time
        inputs that produced it, recorded verbatim into the audit log."""
        cfg = self.cfg
        est = self.rs.mean_service()
        # every non-retired slot's queue counts: work stranded on a crashed
        # (detector-failed) replica is still demand the survivors must
        # absorb, so lost capacity re-provisions instead of hiding the
        # backlog (DESIGN.md §14). For healthy runs this matches the old
        # routable-only sum — draining queues are empty post-requeue.
        backlog = sum(len(q) for i, q in enumerate(self.rs.queues)
                      if not self.rs.retired[i])
        evidence: Dict[str, Any] = {
            "lambda": lam, "est_service_s": est, "backlog": backlog,
        }
        if est <= 0.0:
            evidence.update(n_rate=0, n_backlog=0, want=cfg.min_replicas)
            return cfg.min_replicas, evidence      # no signal yet
        slo = self.slo() if callable(self.slo) else self.slo
        drain = cfg.drain_target if cfg.drain_target is not None else slo
        n_rate = math.ceil(lam * est / cfg.utilization_cap)
        n_backlog = math.ceil(backlog * est / drain) if drain > 0 else 0
        want = min(max(n_rate, n_backlog, cfg.min_replicas),
                   cfg.max_replicas)
        evidence.update(drain_target_s=drain, n_rate=n_rate,
                        n_backlog=n_backlog, want=want)
        return want, evidence

    def tick(self, now: float) -> None:
        """One control period: reap finished drains, sample the routed
        arrival rate, compare the target to live capacity, apply
        hysteresis, act."""
        cfg = self.cfg
        self.rs.reap(now)
        routed = self.metrics.counter(M.QUERIES_ROUTED, model=self.model_id)
        lam = (routed - self._last_routed) / cfg.tick
        self._last_routed = routed
        want, evidence = self._target(lam)
        live = self.rs.n_live
        if want > live:
            self._down_streak = 0
            self._up_streak += 1
            if self._up_streak >= cfg.up_ticks:
                for _ in range(want - live):
                    self.rs.add_replica(self.make_replica(self.model_id),
                                        now=now)
                    self.metrics.inc(M.REPLICAS_ADDED, model=self.model_id)
                    if self.audit is not None:
                        # one record per replica added, so the audit grow
                        # count equals the report's replicas_added counter
                        self.audit.record(
                            now, "autoscaler", "grow", model=self.model_id,
                            evidence={**evidence, "live": self.rs.n_live})
                self._up_streak = 0
                self.events.append({"t": now, "action": "up",
                                    "want": want, "live": self.rs.n_live})
        elif want < live and live > cfg.min_replicas:
            self._up_streak = 0
            self._down_streak += 1
            if self._down_streak >= cfg.down_ticks:
                # one replica per tick once the streak is earned; retire the
                # slowest routable replica (ties: the most recently added)
                ri = max(self.rs.routable(),
                         key=lambda i: (self.rs.est_service(i), i))
                self.rs.retire_replica(ri, now=now)
                self.metrics.inc(M.REPLICAS_RETIRED, model=self.model_id)
                if self.audit is not None:
                    self.audit.record(
                        now, "autoscaler", "drain", model=self.model_id,
                        evidence={**evidence, "replica": ri,
                                  "replica_est_service_s":
                                      self.rs.est_service(ri),
                                  "live": self.rs.n_live})
                self._down_streak = cfg.down_ticks    # stay armed while low
                self.events.append({"t": now, "action": "down",
                                    "want": want, "live": self.rs.n_live})
        else:
            self._up_streak = 0
            self._down_streak = 0
        live = self.rs.n_live
        self.peak_live = max(self.peak_live, live)
        self.timeline.append([round(now, 9), live])

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        """Control-plane section of the run report."""
        return {
            "model": self.model_id,
            "live": self.rs.n_live,
            "peak_live": self.peak_live,
            "total_slots": len(self.rs.replicas),
            "added": self.metrics.counter(M.REPLICAS_ADDED,
                                          model=self.model_id),
            "retired": self.metrics.counter(M.REPLICAS_RETIRED,
                                            model=self.model_id),
            "events": self.events,
            "timeline": self.timeline,
            "replicas": self.rs.replica_stats(),
        }
