"""Replica routing strategies (control plane, DESIGN.md §10).

Clipper replicates containers for throughput (paper §4.4.1, Fig 6) but its
dispatch assumes homogeneous replicas. A dynamic cluster is heterogeneous:
a logical model may be served by a fast small variant and a slow large one,
or by replicas on differently-loaded hosts. ``least_loaded`` (the
frontend's default, queue-length balancing) sends half the traffic to the
slow replica; ``LeastExpectedCompletion`` instead routes each query to the
replica that would *finish* it first, using the per-replica service-time
stats ``ReplicaSet`` tracks.

Routers are plain callables ``(replica_set, now) -> replica_index`` so the
frontend stays decoupled from this package.
"""

from __future__ import annotations

from repro.core.containers import ReplicaSet


def least_loaded(rs: ReplicaSet, now: float) -> int:
    """Shortest queue among routable replicas — the frontend's default,
    exposed here so plans can name it."""
    return min(rs.candidates(), key=lambda i: (len(rs.queues[i]), i))


class LeastExpectedCompletion:
    """Route to the replica with the earliest expected completion time:

        ECT(i) = max(free_at[i] - now, 0) + (backlog_i + 1) * E[service_i]

    where ``E[service_i]`` is the replica's observed mean service seconds
    per query (``ReplicaSet.est_service``). Replicas without observations
    use ``default_service`` (0 = optimistic, so fresh replicas attract work
    and build stats immediately). Ties break on backlog then index, so the
    choice is deterministic.

    Each call leaves the decision's evidence in ``last_attrs`` — the
    chosen replica's expected completion seconds — which the frontend
    merges into the query's queue span when tracing is on, so a flamegraph
    shows what the router *predicted* next to what actually happened."""

    def __init__(self, default_service: float = 0.0):
        self.default_service = default_service
        self.last_attrs = {}

    def __call__(self, rs: ReplicaSet, now: float) -> int:
        ri = min(rs.candidates(), key=lambda i: (
            rs.expected_completion(i, now, self.default_service),
            len(rs.queues[i]), i))
        self.last_attrs = {
            "ect_s": rs.expected_completion(ri, now, self.default_service)}
        return ri


ROUTERS = {
    "least_loaded": lambda: least_loaded,
    "lect": LeastExpectedCompletion,
}


def make_router(name: str):
    if name not in ROUTERS:
        raise KeyError(f"unknown router {name!r}; have {sorted(ROUTERS)}")
    return ROUTERS[name]()
