"""SLO-aware control plane (DESIGN.md §10): the subsystem that closes the
loop between the shared telemetry (``core/metrics.py``) and serving
capacity.

* ``autoscaler`` — per-model reactive replica controller with hysteresis
  and a queueing-model target (InferLine-style);
* ``admission`` — early load shedding: reject-or-degrade queries whose
  deadline is already unmeetable given the backlog;
* ``router``    — heterogeneity-aware routing by least expected completion
  time instead of queue length;
* ``plan``      — ``ClusterPlan`` + the deterministic tick-driven driver
  (``python -m repro.cluster.run``) that replays any workload trace through
  either serving stack with the control plane active, emitting byte-
  identical ``repro.metrics/v1`` reports per seed.
"""

from repro.cluster.admission import SloAdmission, expected_delay
from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
from repro.cluster.plan import (CLUSTER_DEFAULTS, ClusterPlan,
                                cluster_scenario, replica_factory, run_plan,
                                run_plan_json)
from repro.cluster.router import (LeastExpectedCompletion, least_loaded,
                                  make_router)

__all__ = [
    "SloAdmission", "expected_delay",
    "Autoscaler", "AutoscalerConfig",
    "CLUSTER_DEFAULTS", "ClusterPlan", "cluster_scenario", "replica_factory",
    "run_plan", "run_plan_json",
    "LeastExpectedCompletion", "least_loaded", "make_router",
]
