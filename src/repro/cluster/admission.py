"""SLO-aware admission control: early load shedding (DESIGN.md §10).

Clipper's straggler mitigation (paper §5.2.2) salvages queries *after* they
blow the deadline; admission control refuses work whose deadline is already
unmeetable *before* it joins a queue, so overload degrades into explicit
sheds instead of a collapse of every in-flight query's latency (the
InferLine observation). The expected delay for a query is estimated from
current backlog and the per-replica service stats the control plane already
tracks:

    delay(model) = min over routable replicas i of
                   max(free_at[i] - now, 0) + (backlog_i + 1) * E[service_i]

Two policies:

* ``shed``    — reject the query outright when *no* chosen model can meet
                its deadline (and nothing is cached);
* ``degrade`` — first narrow the ensemble to the models that can meet the
                deadline (counted as ``queries.degraded``), shedding only
                when none remain.

Shed and degraded queries are reported through the shared telemetry schema
(``admission.shed`` / ``admission.degraded``), and sheds count against SLO
attainment — the controller cannot game the metric by rejecting everything.

``LMAdmission`` applies the same idea in front of the continuous-batching
``LMServer``: expected wait is the queued backlog spread over the decode
slots at the observed engine-seconds per request.
"""

from __future__ import annotations

from typing import List, Sequence

from repro.core import metrics as M
from repro.core.containers import ReplicaSet
from repro.core.interfaces import Query

POLICIES = ("shed", "degrade")


def expected_delay(rs: ReplicaSet, now: float,
                   default_service: float = 0.0) -> float:
    """Expected queueing + service delay for a query enqueued now — the
    best (earliest) expected completion across routable replicas."""
    # deliberately narrower than ReplicaSet.candidates(): when every replica
    # has failed (statically, or marked down by the failure detector —
    # DESIGN.md §14), the expected delay is infinite and a finite margin
    # never admits — the query sheds rather than being estimated against
    # the dead fallback slot candidates() would still enqueue on
    cands = rs.routable() or rs.healthy()
    if not cands:
        return float("inf")
    return min(rs.expected_completion(i, now, default_service)
               for i in cands)


class SloAdmission:
    """Admission controller for the Clipper frontend (and, via ``admit_lm``,
    the LMServer). ``margin`` scales the delay estimate: > 1 sheds earlier
    (more headroom), < 1 gambles on the estimate being pessimistic."""

    def __init__(self, *, policy: str = "degrade", margin: float = 1.0,
                 default_service: float = 0.0):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}: {policy!r}")
        self.policy = policy
        self.margin = margin
        self.default_service = default_service

    # -- frontend hook (Clipper.submit / submit_stage) ------------------
    def admit(self, clip, q: Query, chosen: Sequence[str], *,
              cached: bool = False,
              shed_counter: str = M.QUERIES_SHED,
              degraded_counter: str = M.QUERIES_DEGRADED,
              trace_parent=None) -> List[str]:
        """Return the subset of ``chosen`` to actually enqueue. Empty with
        ``cached=False`` means the query is shed (counted here); empty with
        ``cached=True`` degrades to a cache-only answer.

        ``shed_counter`` / ``degraded_counter`` name the series the
        decision is recorded under — pipeline stage jobs pass stage-scoped
        names so ``admission.shed/degraded`` stay one-per-pipeline-query.
        ``trace_parent``: when the query carries a sampled trace
        (repro.obs), shed/degrade verdicts are recorded as instant events
        under it."""
        slack = (q.deadline - clip.now) if q.deadline is not None else None
        if slack is None:
            return list(chosen)
        delays = {
            mid: expected_delay(clip.replica_sets[mid], clip.now,
                                self.default_service)
            for mid in chosen
        }
        meetable = [mid for mid in chosen
                    if delays[mid] * self.margin <= slack]
        if self.policy == "shed":
            if meetable or cached:
                return list(chosen)
            clip.metrics.inc(shed_counter)
            self._explain(clip, trace_parent, "shed", slack, chosen, [],
                          delays, shed_counter)
            return []
        if not meetable:
            if cached:
                clip.metrics.inc(degraded_counter)
                self._explain(clip, trace_parent, "degrade", slack, chosen,
                              [], delays, degraded_counter)
                return []
            clip.metrics.inc(shed_counter)
            self._explain(clip, trace_parent, "shed", slack, chosen, [],
                          delays, shed_counter)
            return []
        if len(meetable) < len(chosen):
            clip.metrics.inc(degraded_counter)
            self._explain(clip, trace_parent, "degrade", slack, chosen,
                          meetable, delays, degraded_counter)
        return meetable

    def _explain(self, clip, parent, verdict: str, slack: float,
                 chosen: Sequence[str], kept: Sequence[str],
                 delays, counter: str) -> None:
        """Record the verdict: instant event on the query's trace (when
        sampled) and an audit record with the expected-delay evidence."""
        dropped = sorted(set(chosen) - set(kept))
        if parent is not None and getattr(clip, "tracer", None) is not None:
            clip.tracer.event(parent, verdict, "frontend.admission",
                              clip.now,
                              attrs={"slack_s": slack, "dropped": dropped})
        audit = getattr(clip, "audit", None)
        if audit is not None:
            audit.record(
                clip.now, "admission", verdict,
                evidence={"slack_s": slack, "margin": self.margin,
                          "expected_delay_s": dict(sorted(delays.items())),
                          "chosen": list(chosen), "kept": list(kept),
                          "counter": counter})

    # -- LMServer hook (engine.submit) ----------------------------------
    def admit_lm(self, srv, now: float) -> bool:
        """Admit unless the queued backlog alone is expected to eat the
        whole SLO before this request reaches a slot."""
        est = srv.est_request_service()
        if est <= 0.0:
            return True                    # no signal yet: admit
        backlog = len(srv._queue)
        wait = (backlog + 1) * est / max(srv.slots, 1)
        if wait * self.margin <= srv.slo:
            return True
        audit = getattr(srv, "audit", None)
        if audit is not None:
            audit.record(
                now, "admission", "shed", model=srv.model_id,
                evidence={"backlog": backlog, "est_service_s": est,
                          "expected_wait_s": wait, "slo_s": srv.slo,
                          "margin": self.margin, "slots": srv.slots})
        return False
