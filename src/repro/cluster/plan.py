"""ClusterPlan + the deterministic control-loop driver (DESIGN.md §10).

A ``ClusterPlan`` bundles a workload ``Scenario`` with the control-plane
configuration — autoscaling, admission policy, routing strategy, and the
control tick. ``run_plan`` replays the scenario's arrival trace through the
chosen serving stack with the control plane active, invoking the autoscaler
at every tick boundary of the virtual clock, and emits the shared
``repro.metrics/v1`` report plus a ``cluster`` section (replica timeline,
scale events, per-replica stats). Everything is a pure function of the
plan, so the same plan run twice yields byte-identical JSON.

The cluster scenario defaults differ from the plain workload defaults:
one model, unique queries, and a heavier per-item cost (2 ms), so a single
replica saturates near 450 qps under the 20 ms SLO — the regime where a
flash crowd actually needs the control plane (paper Fig 6 territory).
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.cluster.admission import SloAdmission
from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
from repro.cluster.router import make_router
from repro.faults import FaultPlan, RecoveryPolicy, attach_faults
from repro.core import metrics as M
from repro.core.containers import JaxModelContainer, linear_latency
from repro.core.frontend import make_clipper
from repro.workloads import traces as T
from repro.workloads.scenario import (D_FEAT, SCENARIOS, Scenario,
                                      ScenarioRunner, frontend_models,
                                      trace_meta)

# Overrides applied by ``cluster_scenario`` on top of the named workload
# scenarios: the control-plane regime (single capacity-limited model).
CLUSTER_DEFAULTS: Dict[str, Any] = dict(
    ensemble=1, replicas=1, pool=0, per_item_latency=2e-3)


def cluster_scenario(name: str, **overrides: Any) -> Scenario:
    """A named workload scenario re-parameterized for control-plane runs."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; have {sorted(SCENARIOS)}")
    return dataclasses.replace(SCENARIOS[name],
                               **{**CLUSTER_DEFAULTS, **overrides})


@dataclass(frozen=True)
class ClusterPlan:
    """One reproducible control-plane run."""

    scenario: Scenario
    stack: str = "frontend"         # frontend | lmserver | pipeline
    autoscale: bool = True          # frontend stack only
    admission: Optional[str] = None          # None | shed | degrade
    router: str = "lect"            # lect | least_loaded
    tick: float = 0.05              # control period (virtual seconds)
    utilization_cap: float = 0.7
    drain_target: Optional[float] = None     # None = the scenario SLO
    min_replicas: int = 1
    max_replicas: int = 8
    up_ticks: int = 1
    down_ticks: int = 4
    cooldown_ticks: int = 12        # quiescent ticks so scale-down settles
    admission_margin: float = 1.0
    # fault injection + recovery (repro.faults, DESIGN.md §14): spec
    # strings attached to the scenario's replicas at build time, seeded by
    # the scenario seed. ``recovery`` arms the frontend's failure detector
    # + hedged retries; with faults but no recovery the run is the
    # collapse baseline bench_faults measures against.
    faults: Tuple[str, ...] = ()
    recovery: bool = True

    def autoscaler_config(self) -> AutoscalerConfig:
        return AutoscalerConfig(
            tick=self.tick, utilization_cap=self.utilization_cap,
            drain_target=self.drain_target, min_replicas=self.min_replicas,
            max_replicas=self.max_replicas, up_ticks=self.up_ticks,
            down_ticks=self.down_ticks)

    def describe(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        del d["scenario"]           # reported separately
        return d


def replica_factory(scenario: Scenario, models: Dict[str, Any]):
    """Deterministic supplier of fresh replicas for the autoscaler: replica
    k of model i draws its latency stream from seed (scenario.seed, i, k),
    so an autoscaled run is byte-identical across runs while every replica
    straggles independently."""
    ids = sorted(models)
    counters: Dict[str, int] = {}

    def make(mid: str) -> JaxModelContainer:
        k = counters.get(mid, 0)
        counters[mid] = k + 1
        i = ids.index(mid)
        lat = linear_latency(
            scenario.base_latency * (1.0 + 0.3 * i),
            scenario.per_item_latency,
            p_straggle=scenario.p_straggle,
            straggle_factor=scenario.straggle_factor,
            rng=np.random.default_rng([scenario.seed, 7000 + i, k]))
        return JaxModelContainer(mid, models[mid], latency_model=lat)

    return make


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def _drive_ticks(serve, submit, trace, autoscalers: List[Autoscaler],
                 plan: ClusterPlan, sampler=None) -> None:
    """Tick-driven replay shared by the frontend and pipeline stacks:
    arrivals are interleaved with event processing as in ``Clipper.replay``,
    but the clock is stepped in control periods and every autoscaler
    observes the world at each boundary. ``serve`` needs ``run`` / ``now``
    (settable) / ``pending``; ``submit(x, ctx, at)`` issues one query.
    ``sampler``: an optional ``repro.obs.FleetSampler`` polled after the
    autoscalers so each sample sees the post-decision fleet state."""
    i, t, idle = 0, 0.0, 0
    while True:
        t += plan.tick
        while i < len(trace) and trace[i][0] <= t:
            at, x, ctx = trace[i]
            serve.run(until=at)
            submit(x, ctx, at)
            i += 1
        serve.run(until=t)
        if serve.now < t:
            # idle gap: advance the virtual clock so delayed batches and
            # drain checks see time passing, then dispatch what became ready
            serve.now = t
            serve.run(until=t)
        for a in autoscalers:
            a.tick(t)
        if sampler is not None:
            sampler.sample_until(t)
        if i >= len(trace) and not serve.pending:
            idle += 1
            # end only after the cooldown AND once every autoscaler has
            # drained back to its floor — a short trace that ends mid-burst
            # must still unwind its scale-ups (one retire per tick, so this
            # terminates within max_replicas extra ticks)
            if (idle > plan.cooldown_ticks
                    and all(a.rs.n_live <= a.cfg.min_replicas
                            for a in autoscalers)):
                break
        else:
            idle = 0


def _decisions_section(metrics, replica_sets, audit=None) -> Dict[str, Any]:
    """Control-plane decision tallies (DESIGN.md §15): grow/drain counts
    per model plus shed/degrade totals — derived from the shared counters,
    so the section is schema-stable whether or not an audit log was
    attached; with one attached its exact per-action counts ride along."""
    return {
        "per_model": {
            mid: {"grow": metrics.counter(M.REPLICAS_ADDED, model=mid),
                  "drain": metrics.counter(M.REPLICAS_RETIRED, model=mid)}
            for mid in sorted(replica_sets)},
        "shed": metrics.counter(M.QUERIES_SHED),
        "degraded": metrics.counter(M.QUERIES_DEGRADED),
        "audit": audit.summary() if audit is not None else None,
    }


def _cluster_section(plan: ClusterPlan, autoscalers: List[Autoscaler],
                     replica_sets, metrics=None,
                     audit=None) -> Dict[str, Any]:
    out = {
        "plan": plan.describe(),
        "autoscalers": [a.summary() for a in autoscalers],
        "replica_sets": {mid: {"live": rs.n_live,
                               "total_slots": len(rs.replicas),
                               "replicas": rs.replica_stats()}
                         for mid, rs in sorted(replica_sets.items())},
    }
    if metrics is not None:
        out["decisions"] = _decisions_section(metrics, replica_sets, audit)
    return out


def _apply_faults(plan: ClusterPlan, clip) -> None:
    """Attach the plan's fault specs to the stack's replica sets (seeded by
    the scenario seed) and arm recovery on the frontend event loop."""
    if plan.faults:
        attach_faults(clip.replica_sets,
                      FaultPlan.from_specs(plan.faults,
                                           seed=plan.scenario.seed))
    if plan.faults and plan.recovery:
        clip.recovery = RecoveryPolicy()


def _run_frontend(plan: ClusterPlan, tracer=None, sampler=None,
                  audit=None) -> Dict[str, Any]:
    s = plan.scenario
    models, lat = frontend_models(s)
    admission = (SloAdmission(policy=plan.admission,
                              margin=plan.admission_margin)
                 if plan.admission else None)
    clip = make_clipper(models, "exp4", slo=s.slo, replicas=s.replicas,
                        latency_models=lat, batch_delay=s.batch_delay,
                        seed=s.seed, router=make_router(plan.router),
                        admission=admission, tracer=tracer, audit=audit)
    _apply_faults(plan, clip)
    autoscalers: List[Autoscaler] = []
    if plan.autoscale:
        factory = replica_factory(s, models)
        cfg = plan.autoscaler_config()
        for mid in sorted(clip.replica_sets):
            autoscalers.append(Autoscaler(clip.replica_sets[mid], factory,
                                          clip.metrics, cfg, slo=s.slo,
                                          audit=audit))
    if sampler is not None:
        sampler.bind(metrics=clip.metrics, tracer=tracer)
        sampler.add_probe(clip.timeseries_probe)
    trace = T.query_trace(s.arrival_times(), s.seed, d_feat=D_FEAT,
                          pool=s.pool)
    _drive_ticks(clip, lambda x, ctx, at: clip.submit(
        x, context_id=ctx, arrival_time=at), trace, autoscalers, plan,
        sampler)
    rep = clip.report()
    rep["cluster"] = _cluster_section(plan, autoscalers, clip.replica_sets,
                                      clip.metrics, audit)
    return rep


def _run_pipeline(plan: ClusterPlan, tracer=None, sampler=None,
                  audit=None) -> Dict[str, Any]:
    """Pipeline stack with per-stage provisioning: every stage model gets
    its own autoscaler whose drain target is the *stage's* share of the
    pipeline SLO (planner split), so a hot verify tier grows independently
    of an idle draft tier."""
    from repro.pipeline.scenario import (build_executor, pipeline_models,
                                         pipeline_replica_factory)

    s = plan.scenario
    admission = (SloAdmission(policy=plan.admission,
                              margin=plan.admission_margin)
                 if plan.admission else None)
    zoo = pipeline_models(s)        # one zoo: executor + replica factory
    ex = build_executor(s, "cascade", admission=admission,
                        router=make_router(plan.router), zoo=zoo,
                        tracer=tracer, audit=audit)
    _apply_faults(plan, ex.clip)
    autoscalers: List[Autoscaler] = []
    if plan.autoscale:
        factory = pipeline_replica_factory(s, zoo[0])
        cfg = plan.autoscaler_config()
        for mid in sorted(ex.replica_sets):
            # callable: the drain target follows the planner's live replans
            # instead of freezing at the prior-based initial split
            stage_slo = (lambda mid=mid:
                         ex.split.shares[ex.stage_of[mid]])
            autoscalers.append(Autoscaler(ex.replica_sets[mid], factory,
                                          ex.metrics, cfg, slo=stage_slo,
                                          audit=audit))
    if sampler is not None:
        sampler.bind(metrics=ex.metrics, tracer=tracer)
        sampler.add_probe(ex.timeseries_probe)
    trace = T.query_trace(s.arrival_times(), s.seed, d_feat=D_FEAT,
                          pool=s.pool)
    _drive_ticks(ex.clip, lambda x, ctx, at: ex.submit(x, arrival_time=at),
                 trace, autoscalers, plan, sampler)
    rep = ex.report()
    rep["cluster"] = _cluster_section(plan, autoscalers, ex.replica_sets,
                                      ex.metrics, audit)
    return rep


def _run_lmserver(plan: ClusterPlan, tracer=None, sampler=None,
                  audit=None) -> Dict[str, Any]:
    s = plan.scenario
    if plan.faults:
        # replica-oriented fault specs have no target here: the LM stack
        # models faults per-request (serving.engine faults=RequestFaults)
        raise ValueError("fault plans apply to the frontend/pipeline "
                         "stacks; the lmserver stack takes "
                         "RequestFaults on the engine")
    admission = (SloAdmission(policy=plan.admission,
                              margin=plan.admission_margin)
                 if plan.admission else None)
    runner = ScenarioRunner(s, tracer=tracer, sampler=sampler, audit=audit)
    rep = runner.run_lmserver(admission=admission)
    rep["cluster"] = {"plan": plan.describe(), "autoscalers": [],
                      "replica_sets": {},
                      "decisions": {
                          "per_model": {},
                          "shed": rep["admission"]["shed"],
                          "degraded": rep["admission"]["degraded"],
                          "audit": (audit.summary()
                                    if audit is not None else None)}}
    return rep


def run_plan(plan: ClusterPlan, *, tracer=None, sampler=None,
             audit=None) -> Dict[str, Any]:
    """Execute the plan; returns the shared-schema report with the extra
    ``cluster`` section and trace provenance ``meta``. ``tracer``: an
    optional ``repro.obs.Tracer`` threaded into the chosen stack;
    ``sampler`` / ``audit``: optional ``repro.obs`` FleetSampler /
    AuditLog, attached the same way (off by default, no hot-path cost)."""
    if plan.stack == "frontend":
        rep = _run_frontend(plan, tracer, sampler, audit)
    elif plan.stack == "lmserver":
        rep = _run_lmserver(plan, tracer, sampler, audit)
    elif plan.stack == "pipeline":
        rep = _run_pipeline(plan, tracer, sampler, audit)
    else:
        raise ValueError(f"unknown stack: {plan.stack}")
    rep["scenario"] = dataclasses.asdict(plan.scenario)
    rep["meta"] = trace_meta(plan.scenario)
    return rep


def run_plan_json(plan: ClusterPlan, *, tracer=None, sampler=None,
                  audit=None) -> str:
    """Stable JSON rendering — byte-identical for identical plans."""
    return json.dumps(run_plan(plan, tracer=tracer, sampler=sampler,
                               audit=audit), sort_keys=True, indent=2)
