"""Decoder-only transformer covering the dense / moe / vlm families.

Layers are stacked and scanned (compile time O(1) in depth). Per-layer
metadata (sliding-window size) rides along as scan inputs so hybrid
global/window stacks share one scan. The VLM frontend is a stub: precomputed
patch embeddings arrive in the batch and are concatenated ahead of the text
embeddings (DESIGN.md §4)."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import compat_shard_map, shard
from repro.models import moe as moe_lib
from repro.models.api import Model
from repro.models.common import (
    Spec, attn_qkv, attn_specs, attention_decode, attention_decode_auto,
    attention_prefill, attention_train, axes_tree, cache_update, chunked_loss,
    embed_specs, embed_tokens, glu_apply, glu_specs, init_tree,
    last_valid_slice, lm_head, rmsnorm, rope, stacked, DEFAULT_DTYPE,
)


def _layer_specs(cfg: ModelConfig, nq: int, nkv: int, hd: int) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "ln1": Spec((cfg.d_model,), ("embed",), "ones"),
        "attn": attn_specs(cfg.d_model, nq, nkv, hd, cfg.qkv_bias),
        "ln2": Spec((cfg.d_model,), ("embed",), "ones"),
    }
    if cfg.family == "moe":
        specs["moe"] = moe_lib.moe_specs(cfg.d_model, cfg.d_ff, cfg.num_experts)
    else:
        specs["ffn"] = glu_specs(cfg.d_model, cfg.d_ff)
    return specs


def _layer_windows(cfg: ModelConfig) -> list:
    """Per-layer window sizes (0 = full attention), as static Python ints."""
    w = [cfg.window] * cfg.num_layers
    for i in cfg.global_layers:
        w[i] = 0
    return w


def build(cfg: ModelConfig, mesh, rules, *, remat: str = "full",
          q_block: int = 512, k_block: int = 1024) -> Model:
    tp = mesh.shape.get("model", 1)
    pd = cfg.padded(tp)
    nq, nkv, hd, V = pd.num_q_heads, pd.num_kv_heads, pd.head_dim, pd.vocab_size
    d, L = cfg.d_model, cfg.num_layers
    eps = cfg.norm_eps
    from repro.distributed.sharding import norm_axes
    batch_axes = tuple(a for a in norm_axes(rules.get("batch"))
                       if a in mesh.shape)
    moe_dims = None
    if cfg.family == "moe":
        moe_dims = moe_lib.MoEDims(cfg.num_experts, cfg.num_experts_per_tok,
                                   cfg.moe_capacity_factor, d, cfg.d_ff)

    specs = {
        "embed": embed_specs(V, d),
        "layers": stacked(_layer_specs(cfg, nq, nkv, hd), L),
    }
    static_windows = _layer_windows(cfg)
    windows = jnp.asarray(static_windows, jnp.int32)

    def _ffn(lp, h):
        if cfg.family == "moe":
            return moe_lib.moe_apply(
                lp["moe"], h, moe_dims, mesh=mesh, batch_axes=batch_axes,
                fsdp_axis=_axis(rules, "fsdp"), ffn2d_axis=_axis(rules, "expert_ffn"))
        return glu_apply(lp["ffn"], h), jnp.float32(0.0)

    # ---------------- train ----------------
    def layer_train(x, lp, window):
        h = rmsnorm(x, lp["ln1"], eps)
        q, k, v = attn_qkv(lp["attn"], h, nq, nkv, hd)
        S = x.shape[1]
        pos = jnp.arange(S)[None, :]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        o = attention_train(q, k, v, causal=True, window=window)
        x = x + shard(o.reshape(*x.shape[:2], nq * hd) @ lp["attn"]["wo"],
                      "batch", "seq", "embed")
        h = rmsnorm(x, lp["ln2"], eps)
        y, aux = _ffn(lp, h)
        x = x + shard(y, "batch", "seq", "embed")
        return x, aux

    if remat == "full":
        layer_train = jax.checkpoint(layer_train,
                                     policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        layer_train = jax.checkpoint(
            layer_train, policy=jax.checkpoint_policies.checkpoint_dots)

    def _backbone_train(params, x):
        def body(carry, xs):
            x, aux = carry
            lp, window = xs
            x, a = layer_train(x, lp, window)
            return (x, aux + a), None
        (x, aux), _ = lax.scan(body, (x, jnp.float32(0.0)),
                               (params["layers"], windows))
        return x, aux

    def _embed_input(params, batch):
        x = embed_tokens(params["embed"], batch["tokens"])
        if cfg.frontend == "vision" and "prefix_embeddings" in batch:
            pre = batch["prefix_embeddings"].astype(x.dtype)
            x = jnp.concatenate([shard(pre, "batch", None, "embed"), x], axis=1)
        return x

    def loss_fn(params, batch):
        x = _embed_input(params, batch)
        x, aux = _backbone_train(params, x)
        n_text = batch["tokens"].shape[1]
        x = x[:, -n_text:]   # loss over text positions only (vlm prefix excluded)
        ce = chunked_loss(params["embed"], x, batch["labels"], eps)
        return ce + 0.01 * aux

    # ---------------- prefill ----------------
    cp = rules.get("seq") == "model"   # context-parallel prefill (§Perf)

    def _cp_attention(q, k, v, window):
        """Sequence-sharded attention: each model-rank holds an S/tp slice;
        K/V are all-gathered once per layer (bytes << the TP activation
        all-reduces this replaces — EXPERIMENTS.md §Perf granite)."""
        from jax.sharding import PartitionSpec as P
        bspec = batch_axes if batch_axes else None
        spec = P(bspec, "model", None, None)

        def body(ql, kl, vl):
            kf = lax.all_gather(kl, "model", axis=1, tiled=True)
            vf = lax.all_gather(vl, "model", axis=1, tiled=True)
            off = lax.axis_index("model") * ql.shape[1]
            return attention_prefill(ql, kf, vf, causal=True, window=window,
                                     q_block=min(q_block, ql.shape[1]),
                                     k_block=k_block, q_offset=off)

        return compat_shard_map(body, mesh, (spec, spec, spec),
                                spec)(q, k, v)

    def prefill(params, batch, max_len: Optional[int] = None):
        x = _embed_input(params, batch)
        B, S, _ = x.shape
        Smax = max_len or S
        # per-sample valid prompt length (right-padded batch, serving length
        # ladder). Causal masking already keeps real rows clean; kv_valid
        # additionally zeroes the junk rows' attention mass.
        vl = batch.get("lengths")

        def body(x, xs):
            lp, window = xs
            attn_p = lp["attn"]
            if cp:
                # weights stored TP-sharded; gathered per layer (cheaper on
                # the wire than per-token activation all-reduces at 32k seq)
                attn_p = jax.tree.map(lambda w: shard(w, *((None,) * w.ndim)),
                                      attn_p)
                lp = dict(lp, attn=attn_p,
                          ffn=jax.tree.map(
                              lambda w: shard(w, *((None,) * w.ndim)),
                              lp["ffn"]) if "ffn" in lp else lp.get("ffn"))
            h = rmsnorm(x, lp["ln1"], eps)
            q, k, v = attn_qkv(attn_p, h, nq, nkv, hd)
            pos = jnp.arange(S)[None, :]
            q = rope(q, pos, cfg.rope_theta)
            k = rope(k, pos, cfg.rope_theta)
            if cp:
                o = _cp_attention(q, k, v, window)
            else:
                o = attention_prefill(q, k, v, causal=True, window=window,
                                      q_block=q_block, k_block=k_block,
                                      kv_valid=vl)
            x = x + shard(o.reshape(B, S, nq * hd) @ attn_p["wo"],
                          "batch", "seq", "embed")
            h2 = rmsnorm(x, lp["ln2"], eps)
            y, _ = _ffn(lp, h2)
            x = x + shard(y, "batch", "seq", "embed")
            if Smax > S:
                pad = [(0, 0), (0, Smax - S), (0, 0), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            return x, (k, v)

        x, (ks, vs) = lax.scan(body, x, (params["layers"], windows))
        x_last = x[:, -1:, :] if vl is None else last_valid_slice(x, vl)
        logits = lm_head(params["embed"], x_last, eps)[:, 0]
        lengths = (jnp.full((B,), S, jnp.int32) if vl is None
                   else vl.astype(jnp.int32))
        cache = {"k": ks, "v": vs, "lengths": lengths}
        return logits, cache

    # ---------------- decode ----------------
    # kernel-backend dispatch needs a static window; when every layer shares
    # one window size (the common case — smollm/granite/qwen are all-global)
    # the scanned per-layer window is bypassed with the static value
    uniform_window = (static_windows[0]
                      if len(set(static_windows)) == 1 else None)

    def decode_step(params, cache, tokens, lengths):
        """tokens: [B,1]; lengths: [B] current context length per sample."""
        x = embed_tokens(params["embed"], tokens)
        B = x.shape[0]

        def body(x, xs):
            lp, window, k_l, v_l = xs
            h = rmsnorm(x, lp["ln1"], eps)
            q, k, v = attn_qkv(lp["attn"], h, nq, nkv, hd)
            q = rope(q, lengths[:, None], cfg.rope_theta)
            k = rope(k, lengths[:, None], cfg.rope_theta)
            k_l, v_l = cache_update(k_l, v_l, k, v, lengths)
            if uniform_window is not None:
                o = attention_decode_auto(q, k_l, v_l, lengths + 1,
                                          window=uniform_window)
            else:
                o = attention_decode(q, k_l, v_l, lengths + 1, window=window)
            x = x + shard(o.reshape(B, 1, nq * hd) @ lp["attn"]["wo"],
                          "batch", None, "embed")
            h2 = rmsnorm(x, lp["ln2"], eps)
            y, _ = _ffn(lp, h2)
            x = x + shard(y, "batch", None, "embed")
            return x, (k_l, v_l)

        x, (ks, vs) = lax.scan(body, x,
                               (params["layers"], windows, cache["k"], cache["v"]))
        logits = lm_head(params["embed"], x, eps)[:, 0]
        new_cache = {"k": ks, "v": vs, "lengths": lengths + 1}
        return logits, new_cache

    def init_cache(batch: int, max_len: int):
        # distinct buffers per leaf: the serving engine donates the cache
        # into its jitted scatter/decode, and XLA rejects aliased donations
        shape = (L, batch, max_len, nkv, hd)
        return {"k": jnp.zeros(shape, DEFAULT_DTYPE),
                "v": jnp.zeros(shape, DEFAULT_DTYPE),
                "lengths": jnp.zeros((batch,), jnp.int32)}

    def cache_axes(batch: int, max_len: int):
        # "seq" resolves to None in standard rules (kv_heads takes model);
        # under context-parallel prefill it resolves to model (and the
        # duplicate mesh-axis use drops kv_heads) — see sharding.spec()
        kv = (None, "batch", "seq", "kv_heads", None)
        return {"k": kv, "v": kv, "lengths": ("batch",)}

    return Model(
        cfg=cfg,
        init=lambda rng: init_tree(rng, specs),
        param_axes=axes_tree(specs),
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_axes=cache_axes,
        # moe excluded from prompt padding: junk tokens contend for expert
        # capacity and can displace real tokens' expert assignments
        extras={"padded": pd, "prompt_pad": cfg.family != "moe"},
    )


def _axis(rules, name):
    v = rules.get(name)
    if isinstance(v, tuple):
        v = v[0] if v else None
    return v
