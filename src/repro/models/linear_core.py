"""Chunked linear-attention core shared by mLSTM (xlstm) and SSD (hymba).

Both are matrix-memory recurrences with per-step scalar gates:

    S_t = f_t * S_{t-1} + i_t * k_t v_t^T          (S: [dk, dv] per head)
    y_t = q_t · S_t

The chunkwise-parallel form (GLA/mamba2 style) computes W steps at once:
intra-chunk contributions via a decay-masked [W, W] score matrix, inter-chunk
via the carried state — O(S·W) memory, differentiable (plain scan + einsum),
exact (log-space decay ratios are ≤ 0 before exp, so fp32-stable).

The dry-run/train/prefill paths use ``chunked_linear_attention``; decode uses
``linear_attention_step``. The Pallas kernel `kernels/ssd_scan` mirrors the
same math for the TPU hot path.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def chunked_linear_attention(q, k, v, log_f, log_i, *, chunk: int = 256,
                             initial_state=None) -> Tuple[jax.Array, jax.Array]:
    """q,k: [B,S,H,dk]; v: [B,S,H,dv]; log_f,log_i: [B,S,H] (log_f <= 0).

    Returns (y [B,S,H,dv], final_state [B,H,dk,dv]).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    qc = q.reshape(B, nc, chunk, H, dk)
    kc = k.reshape(B, nc, chunk, H, dk)
    vc = v.reshape(B, nc, chunk, H, dv)
    fc = log_f.reshape(B, nc, chunk, H).astype(jnp.float32)
    ic = log_i.reshape(B, nc, chunk, H).astype(jnp.float32)

    S0 = initial_state
    if S0 is None:
        S0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))

    def chunk_step(state, xs):
        qb, kb, vb, fb, ib = xs           # [B,chunk,H,*]
        cum = jnp.cumsum(fb, axis=1)      # inclusive cumulative log-decay
        # inter-chunk: y_state_t = exp(cum_t) * q_t . S0
        y_state = jnp.einsum("bwhk,bhkv->bwhv", qb.astype(jnp.float32), state)
        y_state = y_state * jnp.exp(cum)[..., None]
        # intra-chunk decay-masked scores
        scores = jnp.einsum("bwhk,buhk->bhwu", qb, kb,
                            preferred_element_type=jnp.float32)
        decay = cum[:, :, None, :] - cum[:, None, :, :] + ib[:, None, :, :]
        decay = jnp.where(causal[None, :, :, None], decay, -jnp.inf)
        scores = scores * jnp.exp(decay).transpose(0, 3, 1, 2)
        y_intra = jnp.einsum("bhwu,buhv->bwhv", scores,
                             vc_f := vb.astype(jnp.float32))
        # state update
        tot = cum[:, -1:, :]              # [B,1,H]
        k_scaled = kb.astype(jnp.float32) * jnp.exp(tot - cum + ib)[..., None]
        state = state * jnp.exp(tot[:, 0])[..., None, None] + \
            jnp.einsum("bwhk,bwhv->bhkv", k_scaled, vc_f)
        return state, (y_state + y_intra).astype(v.dtype)

    state, ys = lax.scan(chunk_step, S0,
                         (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
                          vc.transpose(1, 0, 2, 3, 4), fc.transpose(1, 0, 2, 3),
                          ic.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dv)
    return y, state


def pad_mask_gates(log_f, log_i, vl):
    """Neutralize gates at right-pad junk positions (pos >= vl[b]): forget
    gate 1 (log 0) and input gate 0 (log -inf), so the matrix-memory state
    after a padded sequence equals the state after the unpadded prompt
    exactly — junk steps contribute an exact 0 to every chunk sum.
    log_f/log_i: [B,S,H]; vl: [B] valid lengths."""
    ok = jnp.arange(log_f.shape[1])[None, :, None] < vl[:, None, None]
    return jnp.where(ok, log_f, 0.0), jnp.where(ok, log_i, -1e30)


def linear_attention_step(state, q, k, v, log_f, log_i):
    """One decode step. state [B,H,dk,dv]; q,k [B,H,dk]; v [B,H,dv];
    log_f/log_i [B,H]. Returns (y [B,H,dv], new_state)."""
    f = jnp.exp(log_f.astype(jnp.float32))[..., None, None]
    i = jnp.exp(log_i.astype(jnp.float32))[..., None, None]
    outer = jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32),
                       v.astype(jnp.float32))
    state = f * state + i * outer
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), state)
    return y.astype(v.dtype), state


def normalized_readout(y_aug):
    """mLSTM normalizer trick: v was augmented with a ones column; divide the
    first dv outputs by max(|last column|, 1)."""
    y, n = y_aug[..., :-1], y_aug[..., -1:]
    return y / jnp.maximum(jnp.abs(n), 1.0).astype(y.dtype)
