"""Mixture-of-Experts FFN with expert parallelism (shard_map).

Two execution modes (DESIGN.md §6):

* ``ep``   — experts sharded over the ``model`` axis; activations stay
  batch-sharded over ``data`` and *replicated* over ``model``, so dispatch is
  a purely local sort/gather and combine is a single psum over ``model``
  (same collective cost as a TP FFN all-reduce). Optional FSDP storage: the
  ``d_model`` dim of expert weights sharded over ``data``, all-gathered
  on demand per layer (ZeRO-3).
* ``ep2d`` — kimi-scale serving: experts over ``model`` AND each expert's
  ``d_ff`` over ``data`` (1T params cannot be stored 16-way). Tokens are
  all-gathered over ``data`` in sequence chunks, partial-``d_ff`` GLU is
  computed, and one fused psum over ``(data, model)`` combines.

Routing is top-k softmax with per-shard capacity (sort-based ranking — no
[T, E] one-hot matrices) and standard token dropping + switch-style load
balancing aux loss.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import compat_axis_size, compat_shard_map
from repro.models.common import Spec


class MoEDims(NamedTuple):
    num_experts: int
    top_k: int
    capacity_factor: float
    d_model: int
    d_ff: int


def moe_specs(d_model: int, d_ff: int, num_experts: int) -> Dict[str, Spec]:
    return {
        "router": Spec((d_model, num_experts), ("embed", None), fan_in=d_model,
                       dtype=jnp.float32),
        "wi": Spec((num_experts, d_model, d_ff), ("expert", "fsdp", "expert_ffn"),
                   fan_in=d_model),
        "wg": Spec((num_experts, d_model, d_ff), ("expert", "fsdp", "expert_ffn"),
                   fan_in=d_model),
        "wo": Spec((num_experts, d_ff, d_model), ("expert", "expert_ffn", "fsdp"),
                   fan_in=d_ff),
    }


def _route(x2d: jax.Array, router: jax.Array, top_k: int):
    """Top-k softmax routing. x2d: [T, d] -> (weights [T,k], experts [T,k], aux)."""
    logits = (x2d.astype(jnp.float32) @ router)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, top_k)               # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balancing loss
    E = router.shape[1]
    dispatch_frac = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    dispatch_frac = dispatch_frac / (x2d.shape[0] * top_k)
    mean_prob = probs.mean(axis=0)
    aux = E * jnp.sum(dispatch_frac * mean_prob)
    return top_p, top_e, aux


def _dispatch_indices(top_e: jax.Array, e_lo: int, e_hi: int, capacity: int,
                      num_local: int):
    """Sort-based capacity assignment for experts in [e_lo, e_hi).

    Returns (rows [N], slots [N], keep [N]) where N = T*k; slot is the
    destination row in a [num_local * capacity] buffer (clipped when dropped).
    """
    Tk = top_e.size
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)             # group by expert
    sorted_e = flat_e[order]
    # rank within expert group = index - start index of that group
    same_as_prev = jnp.concatenate([jnp.array([False]),
                                    sorted_e[1:] == sorted_e[:-1]])
    # rank = arange - index of first element of the group
    idx = jnp.arange(Tk)
    group_start = jnp.where(same_as_prev, 0, idx)
    group_start = lax.associative_scan(jnp.maximum, group_start)
    rank = idx - group_start
    local = (sorted_e >= e_lo) & (sorted_e < e_hi)
    keep = local & (rank < capacity)
    slot = (sorted_e - e_lo) * capacity + jnp.minimum(rank, capacity - 1)
    slot = jnp.where(keep, slot, num_local * capacity)   # overflow row
    rows = order // top_e.shape[1]                       # source token row
    return rows, slot, keep, order


def _expert_glu(xb, wi, wg, wo):
    """xb: [E_loc, C, d]; weights: [E_loc, d, dff] / [E_loc, dff, d]."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, wg)) * \
        jnp.einsum("ecd,edf->ecf", xb, wi)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _moe_local(x2d, params, dims: MoEDims, e_lo, num_local, capacity):
    """Route + dispatch + expert GLU + combine for one device's tokens.

    x2d: [T, d] local tokens with *full* d_model and full d_ff weights.
    Returns partial output [T, d] (sum of local experts' contributions) + aux.
    """
    T, d = x2d.shape
    top_p, top_e, aux = _route(x2d, params["router"], dims.top_k)
    rows, slot, keep, order = _dispatch_indices(
        top_e, e_lo, e_lo + num_local, capacity, num_local)
    buf = jnp.zeros((num_local * capacity + 1, d), x2d.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], x2d[rows], 0))
    xb = buf[:-1].reshape(num_local, capacity, d)
    yb = _expert_glu(xb, params["wi"], params["wg"], params["wo"])
    yb = yb.reshape(num_local * capacity, d)
    # combine: weighted scatter-add back to token rows
    w = top_p.reshape(-1)[order]
    contrib = jnp.where(keep[:, None], yb[jnp.minimum(slot, num_local * capacity - 1)]
                        * w[:, None].astype(yb.dtype), 0)
    y = jnp.zeros((T, d), x2d.dtype).at[rows].add(contrib)
    return y, aux


def moe_apply(params, x, dims: MoEDims, *, mesh, batch_axes: Tuple[str, ...],
              fsdp_axis: Optional[str], ffn2d_axis: Optional[str],
              chunk_tokens: int = 4096):
    """MoE FFN. x: [B, S, d] (sharded batch_axes over B). Returns (y, aux)."""
    B, S, d = x.shape
    tp = mesh.shape["model"]
    assert dims.num_experts % tp == 0, (dims.num_experts, tp)
    num_local = dims.num_experts // tp
    x_spec = P(batch_axes if batch_axes else None, None, None)
    w_spec = {
        "router": P(None, None),
        "wi": P("model", fsdp_axis, ffn2d_axis),
        "wg": P("model", fsdp_axis, ffn2d_axis),
        "wo": P("model", ffn2d_axis, fsdp_axis),
    }

    if ffn2d_axis is None:
        body = partial(_moe_body_ep, dims=dims, num_local=num_local,
                       fsdp_axis=fsdp_axis, batch_axes=batch_axes)
    else:
        body = partial(_moe_body_ep2d, dims=dims, num_local=num_local,
                       ffn2d_axis=ffn2d_axis, chunk_tokens=chunk_tokens,
                       batch_axes=batch_axes)

    # full-manual over the mesh; under multi-pod training the pod dim is
    # handled by vmap(spmd_axis_name="pod") outside (grad_compress.py), whose
    # batching rule extends these specs with the pod axis automatically.
    y, aux = compat_shard_map(
        body, mesh,
        (w_spec, x_spec),
        (x_spec, P()),
    )(params, x)
    return y, aux


def _capacity(tokens: int, dims: MoEDims) -> int:
    c = int(math.ceil(tokens * dims.top_k * dims.capacity_factor / dims.num_experts))
    return max(4, c)


def _moe_body_ep(params, x, *, dims: MoEDims, num_local: int, fsdp_axis,
                 batch_axes):
    """Per-device body, mode ``ep``.

    Standard: x [B_loc, S, d] replicated over model (TP-style layers).
    DP-major (§Perf): the batch itself is sharded over model — tokens are
    all-gathered over the model column so each expert-owning rank can serve
    them, and the combined output is psummed then sliced back."""
    gather_model = "model" in batch_axes
    if fsdp_axis is not None:   # ZeRO-3: gather this layer's expert weights
        params = dict(params)
        for k in ("wi", "wg"):
            params[k] = lax.all_gather(params[k], fsdp_axis, axis=1, tiled=True)
        params["wo"] = lax.all_gather(params["wo"], fsdp_axis, axis=2, tiled=True)
    B, S, d = x.shape
    if gather_model:
        x = lax.all_gather(x, "model", axis=0, tiled=True)   # [B*tp, S, d]
    Bg = x.shape[0]
    T = Bg * S
    e_lo = lax.axis_index("model") * num_local
    y, aux = _moe_local(x.reshape(T, d), params, dims, e_lo, num_local,
                        _capacity(T, dims))
    if gather_model:
        # each rank only needs its own batch slice back: reduce-scatter
        # (half the wire of psum+slice, and no full-batch transient)
        y = lax.psum_scatter(y.reshape(Bg, S, d), "model",
                             scatter_dimension=0, tiled=True)
    else:
        y = lax.psum(y, "model").reshape(Bg, S, d)
    # routing is identical across model ranks (single copy); mean over batch
    aux = lax.psum(aux, "model") / compat_axis_size("model")
    if batch_axes:
        aux = lax.pmean(aux, batch_axes)
    return y.reshape(B, S, d), aux


def _moe_body_ep2d(params, x, *, dims: MoEDims, num_local: int, ffn2d_axis,
                   chunk_tokens: int, batch_axes):
    """Per-device body, mode ``ep2d``: expert d_ff sharded over `ffn2d_axis`.

    Tokens are all-gathered over the ffn2d axis in chunks; the GLU runs on the
    local d_ff slice; one psum over (ffn2d, model) combines partial outputs.
    """
    B, S, d = x.shape
    T = B * S
    dp = compat_axis_size(ffn2d_axis)
    my_rank = lax.axis_index(ffn2d_axis)
    e_lo = lax.axis_index("model") * num_local
    nchunks = max(1, (T + chunk_tokens - 1) // chunk_tokens)
    while T % nchunks:
        nchunks += 1
    csize = T // nchunks
    x2d = x.reshape(T, d)

    def chunk_step(aux, ci):
        xc = lax.dynamic_slice_in_dim(x2d, ci * csize, csize, axis=0)
        xc_all = lax.all_gather(xc, ffn2d_axis, axis=0, tiled=True)  # [csize*dp, d]
        yc, a = _moe_local(xc_all, params, dims, e_lo, num_local,
                           _capacity(csize * dp, dims))
        yc = lax.psum(yc, (ffn2d_axis, "model"))
        yc_mine = lax.dynamic_slice_in_dim(yc, my_rank * csize, csize, axis=0)
        return aux + a, yc_mine

    aux, ys = lax.scan(chunk_step, jnp.float32(0.0), jnp.arange(nchunks))
    y = ys.reshape(T, d)
    aux = aux / nchunks
    if batch_axes:
        aux = lax.pmean(aux, batch_axes)
    return y.reshape(B, S, d), aux
