"""xLSTM: pair-scanned (mLSTM, sLSTM) blocks — 12 layers = 6 pairs.

mLSTM: matrix memory [dk, dv] per head with sigmoid exponential-free gating,
run through the shared chunked linear-attention core (O(1)-in-seq state ⇒
long_500k applicable). sLSTM: scalar memory with hidden-state recurrence
(inherently sequential; time-scan). See DESIGN.md §4 for deviations from the
published 7:1 block ratio (we pair-scan 1:1).

Sharding: xlstm-125m is DP/FSDP-only by design — at 125 M params TP buys
nothing; the `model` mesh axis is idle (EXPERIMENTS.md notes this; the
long_500k hillclimb revisits it)."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.api import Model
from repro.models.common import (
    Spec, axes_tree, chunked_loss, embed_specs, embed_tokens, init_tree,
    last_valid_slice, lm_head, rmsnorm, stacked, DEFAULT_DTYPE,
)
from repro.models.linear_core import (
    chunked_linear_attention, linear_attention_step, pad_mask_gates,
)


def _mlstm_specs(d: int, nh: int, d_in: int, hd: int) -> Dict[str, Spec]:
    return {
        "ln": Spec((d,), ("embed",), "ones"),
        "w_up": Spec((d, 2 * d_in), ("fsdp", None), fan_in=d),
        "wq": Spec((d_in, nh, hd), ("fsdp", None, None), fan_in=d_in),
        "wk": Spec((d_in, nh, hd), ("fsdp", None, None), fan_in=d_in),
        "wv": Spec((d_in, nh, hd), ("fsdp", None, None), fan_in=d_in),
        "w_gates": Spec((d_in, 2 * nh), ("fsdp", None), fan_in=d_in,
                        dtype=jnp.float32),
        "b_gates": Spec((2 * nh,), (None,), "zeros", dtype=jnp.float32),
        "w_down": Spec((d_in, d), (None, "fsdp"), fan_in=d_in),
    }


def _slstm_specs(d: int) -> Dict[str, Spec]:
    return {
        "ln": Spec((d,), ("embed",), "ones"),
        "w": Spec((d, 4 * d), ("fsdp", None), fan_in=d),
        "r": Spec((d, 4 * d), ("fsdp", None), fan_in=d),
        "b": Spec((4 * d,), (None,), "zeros"),
        "w_out": Spec((d, d), ("fsdp", None), fan_in=d),
    }


def _mlstm_gates(p, c_in):
    """Returns (log_f, log_i) per head, both <= ~0 (sigmoid gating)."""
    raw = c_in.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]
    nh = raw.shape[-1] // 2
    log_f = jax.nn.log_sigmoid(raw[..., :nh] + 4.0)   # bias toward remembering
    log_i = jax.nn.log_sigmoid(raw[..., nh:])
    return log_f, log_i


def _mlstm_qkv(p, c_in, scale):
    q = jnp.einsum("bsd,dhk->bshk", c_in, p["wq"]) * scale
    k = jnp.einsum("bsd,dhk->bshk", c_in, p["wk"]) * scale
    v = jnp.einsum("bsd,dhk->bshk", c_in, p["wv"])
    return q, k, v


def _mlstm_seq(p, x, state, chunk, vl=None):
    """Full-sequence mLSTM block. state: (S [B,nh,hd,hd], n [B,nh,hd])."""
    B, S, d = x.shape
    h = rmsnorm(x, p["ln"])
    up = h @ p["w_up"]
    d_in = up.shape[-1] // 2
    c_in, z = up[..., :d_in], up[..., d_in:]
    nh, hd = p["wq"].shape[1], p["wq"].shape[2]
    q, k, v = _mlstm_qkv(p, c_in, hd ** -0.5)
    log_f, log_i = _mlstm_gates(p, c_in)
    if vl is not None:
        log_f, log_i = pad_mask_gates(log_f, log_i, vl)
    Sm, Nm = state
    y, Sm = chunked_linear_attention(q, k, v, log_f, log_i, chunk=chunk,
                                     initial_state=Sm)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    nrm, Nm2 = chunked_linear_attention(q, k, ones, log_f, log_i, chunk=chunk,
                                        initial_state=Nm[..., None])
    y = y / jnp.maximum(jnp.abs(nrm), 1.0).astype(y.dtype)
    y = y.reshape(B, S, nh * hd) * jax.nn.silu(z)
    return x + y @ p["w_down"], (Sm, Nm2[..., 0])


def _mlstm_step(p, x, state):
    """One-token mLSTM. x: [B,1,d]."""
    B = x.shape[0]
    h = rmsnorm(x, p["ln"])
    up = h @ p["w_up"]
    d_in = up.shape[-1] // 2
    c_in, z = up[..., :d_in], up[..., d_in:]
    nh, hd = p["wq"].shape[1], p["wq"].shape[2]
    q, k, v = _mlstm_qkv(p, c_in, hd ** -0.5)
    log_f, log_i = _mlstm_gates(p, c_in)
    Sm, Nm = state
    sq = lambda a: a[:, 0]
    y, Sm = linear_attention_step(Sm, sq(q), sq(k), sq(v), sq(log_f), sq(log_i))
    nrm, Nm = linear_attention_step(Nm[..., None], sq(q), sq(k),
                                    jnp.ones((B, nh, 1), v.dtype),
                                    sq(log_f), sq(log_i))
    y = y / jnp.maximum(jnp.abs(nrm), 1.0).astype(y.dtype)
    y = y.reshape(B, 1, nh * hd) * jax.nn.silu(z)
    return x + y @ p["w_down"], (Sm, Nm[..., 0])


def _slstm_cell(p, x_t, carry):
    """One sLSTM step. x_t: [B,d]; carry: (c, n, h, m) each [B,d] fp32."""
    c, n, h, m = carry
    raw = (x_t.astype(jnp.float32) @ p["w"].astype(jnp.float32)
           + h @ p["r"].astype(jnp.float32) + p["b"].astype(jnp.float32))
    d = x_t.shape[-1]
    zi, ii, fi, oi = (raw[..., :d], raw[..., d:2 * d],
                      raw[..., 2 * d:3 * d], raw[..., 3 * d:])
    log_f = jax.nn.log_sigmoid(fi + 4.0)
    log_i = jax.nn.log_sigmoid(ii)
    m_new = jnp.maximum(log_f + m, log_i)
    fp = jnp.exp(log_f + m - m_new)
    ip = jnp.exp(log_i - m_new)
    c = fp * c + ip * jnp.tanh(zi)
    n = fp * n + ip
    h_new = jax.nn.sigmoid(oi) * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new), h_new


def _slstm_seq(p, x, state, vl=None):
    B, S, d = x.shape
    h0 = rmsnorm(x, p["ln"])

    if vl is None:
        def step(carry, x_t):
            carry, h_t = _slstm_cell(p, x_t, carry)
            return carry, h_t

        state, hs = lax.scan(step, state, h0.transpose(1, 0, 2))
    else:
        # hidden-state recurrence: gate masking alone cannot preserve h, so
        # junk steps keep the whole carry via select
        valid = jnp.arange(S)[:, None] < vl[None, :]        # [S, B]

        def step(carry, xs):
            x_t, ok = xs
            new, h_t = _slstm_cell(p, x_t, carry)
            carry = tuple(jnp.where(ok[:, None], nc, oc)
                          for nc, oc in zip(new, carry))
            return carry, h_t

        state, hs = lax.scan(step, state, (h0.transpose(1, 0, 2), valid))
    y = hs.transpose(1, 0, 2).astype(x.dtype) @ p["w_out"]
    return x + y, state


def _slstm_step(p, x, state):
    h = rmsnorm(x, p["ln"])
    state, h_t = _slstm_cell(p, h[:, 0], state)
    return x + (h_t.astype(x.dtype) @ p["w_out"])[:, None, :], state


def build(cfg: ModelConfig, mesh, rules, *, remat: str = "full",
          chunk: int = 256, **_) -> Model:
    d, L = cfg.d_model, cfg.num_layers
    assert L % 2 == 0, "xlstm pair-scan needs an even layer count"
    npairs = L // 2
    nh = cfg.num_heads
    d_in = 2 * d
    hd = d_in // nh
    eps = cfg.norm_eps
    V = cfg.padded(mesh.shape.get("model", 1)).vocab_size

    pair_specs = {"m": _mlstm_specs(d, nh, d_in, hd), "s": _slstm_specs(d)}
    specs = {"embed": embed_specs(V, d), "pairs": stacked(pair_specs, npairs)}

    def pair_seq(x, pp, state, chunk_, vl=None):
        x, mstate = _mlstm_seq(pp["m"], x, state["m"], chunk_, vl)
        x, sstate = _slstm_seq(pp["s"], x, state["s"], vl)
        return x, {"m": mstate, "s": sstate}

    def _zero_state(B):
        return {
            "m": (jnp.zeros((npairs, B, nh, hd, hd), jnp.float32),
                  jnp.zeros((npairs, B, nh, hd), jnp.float32)),
            "s": tuple(jnp.zeros((npairs, B, d), jnp.float32) for _ in range(4)),
        }

    def _run_seq(params, x, state, chunk_, vl=None):
        def body(x, xs):
            pp, st_m0, st_m1, st_s = xs
            x, st = pair_seq(x, pp, {"m": (st_m0, st_m1), "s": st_s}, chunk_,
                             vl)
            return x, (st["m"][0], st["m"][1], st["s"])
        if remat != "none":
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, (m0, m1, s) = lax.scan(
            body, x, (params["pairs"], state["m"][0], state["m"][1], state["s"]))
        return x, {"m": (m0, m1), "s": s}

    def loss_fn(params, batch):
        x = embed_tokens(params["embed"], batch["tokens"])
        state = _zero_state(x.shape[0])
        x, _ = _run_seq(params, x, state, chunk)
        return chunked_loss(params["embed"], x, batch["labels"], eps)

    def prefill(params, batch, max_len=None):
        x = embed_tokens(params["embed"], batch["tokens"])
        B = x.shape[0]
        vl = batch.get("lengths")
        state = _zero_state(B)
        x, state = _run_seq(params, x, state, chunk, vl)
        x_last = x[:, -1:, :] if vl is None else last_valid_slice(x, vl)
        logits = lm_head(params["embed"], x_last, eps)[:, 0]
        state["lengths"] = (jnp.full((B,), x.shape[1], jnp.int32)
                            if vl is None else vl.astype(jnp.int32))
        return logits, state

    def decode_step(params, cache, tokens, lengths):
        x = embed_tokens(params["embed"], tokens)

        def body(x, xs):
            pp, st_m0, st_m1, st_s = xs
            x, mstate = _mlstm_step(pp["m"], x, (st_m0, st_m1))
            x, sstate = _slstm_step(pp["s"], x, st_s)
            return x, (mstate[0], mstate[1], sstate)

        x, (m0, m1, s) = lax.scan(
            body, x, (params["pairs"], cache["m"][0], cache["m"][1], cache["s"]))
        logits = lm_head(params["embed"], x, eps)[:, 0]
        return logits, {"m": (m0, m1), "s": s, "lengths": lengths + 1}

    def init_cache(batch: int, max_len: int):
        st = _zero_state(batch)
        st["lengths"] = jnp.zeros((batch,), jnp.int32)
        return st

    def cache_axes(batch: int, max_len: int):
        return {
            "m": ((None, "batch", None, None, None), (None, "batch", None, None)),
            "s": tuple((None, "batch", None) for _ in range(4)),
            "lengths": ("batch",),
        }

    return Model(
        cfg=cfg,
        init=lambda rng: init_tree(rng, specs),
        param_axes=axes_tree(specs),
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_axes=cache_axes,
        extras={"prompt_pad": True},
    )
