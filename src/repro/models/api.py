"""Uniform model API: every architecture builds to a :class:`Model` with the
same five entry points, so the serving engine, trainer, and dry-run treat all
ten assigned architectures identically (this *is* Clipper's "model container"
narrow waist, §4.4 of the paper, applied at the model-definition level)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.configs.base import ModelConfig


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]            # rng -> params
    param_axes: Any                      # logical-axes tree (same structure)
    loss_fn: Callable[..., Any]          # (params, batch) -> scalar loss
    prefill: Callable[..., Any]          # (params, batch) -> (logits, cache)
    decode_step: Callable[..., Any]      # (params, cache, tokens, lengths) -> (logits, cache)
    init_cache: Callable[..., Any]       # (batch, max_len) -> cache
    cache_axes: Callable[..., Any]       # (batch, max_len) -> logical-axes tree
    extras: Dict[str, Any] = field(default_factory=dict)


def build_model(cfg: ModelConfig, mesh, rules, *, remat: str = "full",
                **opts) -> Model:
    """Dispatch on family. mesh/rules drive TP padding and MoE shard_map."""
    from repro.models import transformer, xlstm, hymba, encdec

    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.build(cfg, mesh, rules, remat=remat, **opts)
    if cfg.family == "ssm":
        return xlstm.build(cfg, mesh, rules, remat=remat, **opts)
    if cfg.family == "hybrid":
        return hymba.build(cfg, mesh, rules, remat=remat, **opts)
    if cfg.family == "encdec":
        return encdec.build(cfg, mesh, rules, remat=remat, **opts)
    raise ValueError(f"unknown family {cfg.family!r}")
