"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: ``batch["frames"]`` carries
precomputed frame embeddings [B, S_enc, d]. The decoder operates on text
tokens of length ``seq_len // decoder_ratio`` for train/prefill shapes, and
decodes one token against a seq_len-long encoder memory for decode shapes
(DESIGN.md §4)."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.api import Model
from repro.models.common import (
    Spec, attn_qkv, attn_specs, attention_decode_auto, attention_prefill,
    attention_train, axes_tree, cache_update, chunked_loss, embed_specs,
    embed_tokens, glu_apply, glu_specs, init_tree, last_valid_slice, lm_head,
    rmsnorm, rope, stacked, DEFAULT_DTYPE,
)


def build(cfg: ModelConfig, mesh, rules, *, remat: str = "full",
          q_block: int = 512, k_block: int = 1024, **_) -> Model:
    tp = mesh.shape.get("model", 1)
    pd = cfg.padded(tp)
    nq, nkv, hd, V = pd.num_q_heads, pd.num_kv_heads, pd.head_dim, pd.vocab_size
    d, L, eps = cfg.d_model, cfg.num_layers, cfg.norm_eps

    enc_layer = {
        "ln1": Spec((d,), ("embed",), "ones"),
        "attn": attn_specs(d, nq, nkv, hd, cfg.qkv_bias),
        "ln2": Spec((d,), ("embed",), "ones"),
        "ffn": glu_specs(d, cfg.d_ff),
    }
    dec_layer = {
        "ln1": Spec((d,), ("embed",), "ones"),
        "self": attn_specs(d, nq, nkv, hd, cfg.qkv_bias),
        "ln_x": Spec((d,), ("embed",), "ones"),
        "cross": attn_specs(d, nq, nkv, hd, cfg.qkv_bias),
        "ln2": Spec((d,), ("embed",), "ones"),
        "ffn": glu_specs(d, cfg.d_ff),
    }
    specs = {
        "embed": embed_specs(V, d),
        "enc_norm": Spec((d,), ("embed",), "ones"),
        "enc": stacked(enc_layer, L),
        "dec": stacked(dec_layer, L),
    }

    def _enc_attn(lp, h, train: bool):
        B, S, _ = h.shape
        q, k, v = attn_qkv(lp["attn"], h, nq, nkv, hd)
        pos = jnp.arange(S)[None, :]
        q, k = rope(q, pos, cfg.rope_theta), rope(k, pos, cfg.rope_theta)
        if train:
            o = attention_train(q, k, v, causal=False)
        else:
            o = attention_prefill(q, k, v, causal=False,
                                  q_block=q_block, k_block=k_block)
        return o.reshape(B, S, nq * hd) @ lp["attn"]["wo"]

    def _encode(params, frames, train: bool):
        x = shard(frames.astype(DEFAULT_DTYPE), "batch", None, "embed")

        def body(x, lp):
            x = x + shard(_enc_attn(lp, rmsnorm(x, lp["ln1"], eps), train),
                          "batch", None, "embed")
            x = x + shard(glu_apply(lp["ffn"], rmsnorm(x, lp["ln2"], eps)),
                          "batch", None, "embed")
            return x, None

        body_fn = body
        if train and remat != "none":
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = lax.scan(body_fn, x, params["enc"])
        return rmsnorm(x, params["enc_norm"], eps)

    def _cross_kv(lp, memory):
        B, S, _ = memory.shape
        k = (memory @ lp["cross"]["wk"]).reshape(B, S, nkv, hd)
        v = (memory @ lp["cross"]["wv"]).reshape(B, S, nkv, hd)
        if "bk" in lp["cross"]:
            k = k + lp["cross"]["bk"].reshape(nkv, hd)
            v = v + lp["cross"]["bv"].reshape(nkv, hd)
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
        return k, v

    def _dec_layer_seq(x, lp, memory, train: bool, vl=None):
        B, S, _ = x.shape
        h = rmsnorm(x, lp["ln1"], eps)
        q, k, v = attn_qkv(lp["self"], h, nq, nkv, hd)
        pos = jnp.arange(S)[None, :]
        q, k = rope(q, pos, cfg.rope_theta), rope(k, pos, cfg.rope_theta)
        if train:
            o = attention_train(q, k, v, causal=True)
        else:
            o = attention_prefill(q, k, v, causal=True,
                                  q_block=min(q_block, S),
                                  k_block=min(k_block, S), kv_valid=vl)
        x = x + shard(o.reshape(B, S, nq * hd) @ lp["self"]["wo"],
                      "batch", None, "embed")
        # cross attention
        h = rmsnorm(x, lp["ln_x"], eps)
        qx = (h @ lp["cross"]["wq"]).reshape(B, S, nq, hd)
        if "bq" in lp["cross"]:
            qx = qx + lp["cross"]["bq"].reshape(nq, hd)
        kx, vx = _cross_kv(lp, memory)
        if train:
            ox = attention_train(qx, kx, vx, causal=False)
        else:
            ox = attention_prefill(qx, kx, vx, causal=False,
                                   q_block=min(q_block, S), k_block=k_block)
        x = x + shard(ox.reshape(B, S, nq * hd) @ lp["cross"]["wo"],
                      "batch", None, "embed")
        x = x + shard(glu_apply(lp["ffn"], rmsnorm(x, lp["ln2"], eps)),
                      "batch", None, "embed")
        return x, (k, v)

    def loss_fn(params, batch):
        memory = _encode(params, batch["frames"], train=True)
        x = embed_tokens(params["embed"], batch["tokens"])

        def body(x, lp):
            x, _ = _dec_layer_seq(x, lp, memory, train=True)
            return x, None

        body_fn = body
        if remat != "none":
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = lax.scan(body_fn, x, params["dec"])
        return chunked_loss(params["embed"], x, batch["labels"], eps)

    def prefill(params, batch, max_len=None):
        memory = _encode(params, batch["frames"], train=False)
        x = embed_tokens(params["embed"], batch["tokens"])
        B, S, _ = x.shape
        Smax = max_len or S
        vl = batch.get("lengths")       # per-sample valid decoder-token count

        def body(x, lp):
            x, (k, v) = _dec_layer_seq(x, lp, memory, train=False, vl=vl)
            ck, cv = _cross_kv(lp, memory)
            if Smax > S:
                pad = [(0, 0), (0, Smax - S), (0, 0), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            return x, (k, v, ck, cv)

        x, (ks, vs, cks, cvs) = lax.scan(body, x, params["dec"])
        x_last = x[:, -1:, :] if vl is None else last_valid_slice(x, vl)
        logits = lm_head(params["embed"], x_last, eps)[:, 0]
        lengths = (jnp.full((B,), S, jnp.int32) if vl is None
                   else vl.astype(jnp.int32))
        cache = {"k": ks, "v": vs, "ck": cks, "cv": cvs, "lengths": lengths}
        return logits, cache

    def decode_step(params, cache, tokens, lengths):
        x = embed_tokens(params["embed"], tokens)
        B = x.shape[0]

        def body(x, xs):
            lp, k_l, v_l, ck_l, cv_l = xs
            h = rmsnorm(x, lp["ln1"], eps)
            q, k, v = attn_qkv(lp["self"], h, nq, nkv, hd)
            q = rope(q, lengths[:, None], cfg.rope_theta)
            k = rope(k, lengths[:, None], cfg.rope_theta)
            k_l, v_l = cache_update(k_l, v_l, k, v, lengths)
            o = attention_decode_auto(q, k_l, v_l, lengths + 1)
            x = x + shard(o.reshape(B, 1, nq * hd) @ lp["self"]["wo"],
                          "batch", None, "embed")
            h = rmsnorm(x, lp["ln_x"], eps)
            qx = (h @ lp["cross"]["wq"]).reshape(B, 1, nq, hd)
            if "bq" in lp["cross"]:
                qx = qx + lp["cross"]["bq"].reshape(nq, hd)
            S_enc = ck_l.shape[1]
            enc_len = jnp.full((B,), S_enc, jnp.int32)
            ox = attention_decode_auto(qx, ck_l, cv_l, enc_len)
            x = x + shard(ox.reshape(B, 1, nq * hd) @ lp["cross"]["wo"],
                          "batch", None, "embed")
            x = x + shard(glu_apply(lp["ffn"], rmsnorm(x, lp["ln2"], eps)),
                          "batch", None, "embed")
            return x, (k_l, v_l)

        x, (ks, vs) = lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]))
        logits = lm_head(params["embed"], x, eps)[:, 0]
        return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"],
                        "lengths": lengths + 1}

    def init_cache(batch: int, max_len: int, enc_len: int = 0):
        # distinct buffers per leaf (donation-safe — see transformer)
        kv = (L, batch, max_len, nkv, hd)
        ckv = (L, batch, enc_len or max_len, nkv, hd)
        return {"k": jnp.zeros(kv, DEFAULT_DTYPE),
                "v": jnp.zeros(kv, DEFAULT_DTYPE),
                "ck": jnp.zeros(ckv, DEFAULT_DTYPE),
                "cv": jnp.zeros(ckv, DEFAULT_DTYPE),
                "lengths": jnp.zeros((batch,), jnp.int32)}

    def cache_axes(batch: int, max_len: int, enc_len: int = 0):
        kv = (None, "batch", None, "kv_heads", None)
        return {"k": kv, "v": kv, "ck": kv, "cv": kv, "lengths": ("batch",)}

    return Model(
        cfg=cfg,
        init=lambda rng: init_tree(rng, specs),
        param_axes=axes_tree(specs),
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_axes=cache_axes,
        extras={"padded": pd, "prompt_pad": True},
    )
