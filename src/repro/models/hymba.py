"""Hymba: hybrid blocks with *parallel* attention and mamba(SSD) heads.

Each block feeds the same normed input to (a) GQA attention — sliding window
except for 3 global layers — and (b) an SSD branch (mamba2-style: in-proj,
short causal conv, scalar-decay matrix-state recurrence via the shared
chunked linear core, silu gate, out-proj). Branch outputs are per-branch
RMS-normed and averaged (the Hymba paper's fusion), then a GLU FFN follows.

Layer organization (§Perf memory-term hillclimb): the 3 global-attention
layers are unrolled with full-length caches; the 29 sliding-window layers
are scanned in two segments with *window-sized ring-buffer* caches — the
KV state for long_500k drops from O(L·S) to O(3·S + 29·W). RoPE is applied
at write time, so ring order is irrelevant (attention is permutation-
invariant over KV rows)."""

from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.api import Model
from repro.models.common import (
    Spec, attn_qkv, attn_specs, attention_decode_auto, attention_decode_ring,
    attention_prefill, attention_train, axes_tree, cache_update,
    chunked_loss, embed_specs, embed_tokens, glu_apply, glu_specs, init_tree,
    last_valid_slice, lm_head, ring_cache_update, rmsnorm, rope, stacked,
    DEFAULT_DTYPE,
)
from repro.models.linear_core import (
    chunked_linear_attention, linear_attention_step, pad_mask_gates,
)


def _ssd_specs(d: int, nh: int, hd: int, ds: int, conv_w: int) -> Dict[str, Spec]:
    d_inner = nh * hd
    return {
        "w_in": Spec((d, 2 * d_inner), ("fsdp", "heads"), fan_in=d),
        "conv": Spec((conv_w, d_inner), (None, "heads"), fan_in=conv_w),
        "w_bc": Spec((d, 2 * nh * ds), ("fsdp", "heads"), fan_in=d),
        "w_dt": Spec((d, nh), ("fsdp", "heads"), fan_in=d, dtype=jnp.float32),
        "b_dt": Spec((nh,), ("heads",), "zeros", dtype=jnp.float32),
        "a_log": Spec((nh,), ("heads",), "zeros", dtype=jnp.float32),
        "d_skip": Spec((nh,), ("heads",), "zeros", dtype=jnp.float32),
        "w_out": Spec((d_inner, d), ("heads", "fsdp"), fan_in=d_inner),
    }


def _causal_conv(x, kern, state=None, vl=None):
    """Depthwise causal conv via shifts. x: [B,S,C]; kern: [W,C];
    state: [B,W-1,C] trailing inputs from the previous segment.

    vl: per-sample valid length of a right-padded x — the carried state is
    then the last W-1 *valid* inputs per sample (row t of x lives at row
    t + W-1 of the padded buffer), not the junk tail."""
    B, S, C = x.shape
    W = kern.shape[0]
    if state is None:
        pad = jnp.zeros((B, W - 1, C), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, j:j + S] * kern[j] for j in range(W))
    if vl is None or W == 1:
        new_state = xp[:, -(W - 1):] if W > 1 else xp[:, :0]
    else:
        idx = vl[:, None] + jnp.arange(W - 1)[None, :]      # xp rows of the
        idx = jnp.broadcast_to(idx[:, :, None], (B, W - 1, C))
        new_state = jnp.take_along_axis(xp, idx, axis=1)    # last valid W-1
    return jax.nn.silu(y), new_state


def _ssd_gates(p, x):
    """(log_f, log_i) from dt. log_f = -dt*A <= 0; log_i = log(dt)."""
    dt = jax.nn.softplus(x.astype(jnp.float32) @ p["w_dt"] + p["b_dt"])
    dt = jnp.clip(dt, 1e-4, 8.0)
    A = jnp.exp(p["a_log"])          # positive per-head decay rate
    return -dt * A, jnp.log(dt)


def _ssd_seq(p, x, state, chunk, vl=None):
    """SSD branch over a sequence. state: (conv_state, S [B,nh,ds,hd])."""
    B, S, d = x.shape
    nh = p["w_dt"].shape[1]
    ds = p["w_bc"].shape[1] // (2 * nh)
    hd = p["w_in"].shape[1] // (2 * nh)
    conv_state, Sm = state
    up = x @ p["w_in"]
    d_inner = nh * hd
    xin, z = up[..., :d_inner], up[..., d_inner:]
    xin, conv_state = _causal_conv(xin, p["conv"], conv_state, vl=vl)
    bc = x @ p["w_bc"]
    b = bc[..., :nh * ds].reshape(B, S, nh, ds)
    c = bc[..., nh * ds:].reshape(B, S, nh, ds)
    log_f, log_i = _ssd_gates(p, x)
    if vl is not None:
        log_f, log_i = pad_mask_gates(log_f, log_i, vl)
    v = xin.reshape(B, S, nh, hd)
    y, Sm = chunked_linear_attention(c, b, v, log_f, log_i, chunk=chunk,
                                     initial_state=Sm)
    y = y + v * p["d_skip"].astype(v.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_inner) * jax.nn.silu(z)
    return y @ p["w_out"], (conv_state, Sm)


def _ssd_step(p, x, state):
    """One token. x: [B,1,d]."""
    B = x.shape[0]
    nh = p["w_dt"].shape[1]
    ds = p["w_bc"].shape[1] // (2 * nh)
    hd = p["w_in"].shape[1] // (2 * nh)
    conv_state, Sm = state
    up = x @ p["w_in"]
    d_inner = nh * hd
    xin, z = up[..., :d_inner], up[..., d_inner:]
    xin, conv_state = _causal_conv(xin, p["conv"], conv_state)
    bc = x @ p["w_bc"]
    b = bc[:, 0, :nh * ds].reshape(B, nh, ds)
    c = bc[:, 0, nh * ds:].reshape(B, nh, ds)
    log_f, log_i = _ssd_gates(p, x)
    v = xin.reshape(B, nh, hd)
    y, Sm = linear_attention_step(Sm, c, b, v, log_f[:, 0], log_i[:, 0])
    y = y + v * p["d_skip"].astype(v.dtype)[None, :, None]
    y = y.reshape(B, 1, d_inner) * jax.nn.silu(z)
    return y @ p["w_out"], (conv_state, Sm)


def _segments(cfg: ModelConfig) -> List[int]:
    """SWA segment lengths between consecutive global layers."""
    gl = sorted(cfg.global_layers)
    assert gl and gl[0] == 0, "expect a leading global layer"
    segs = []
    for a, b in zip(gl, gl[1:] + [cfg.num_layers]):
        segs.append(b - a - 1)
    return segs       # e.g. (0,15,31), L=32 -> [14, 15, 0]


def build(cfg: ModelConfig, mesh, rules, *, remat: str = "full",
          chunk: int = 256, q_block: int = 512, k_block: int = 1024,
          **_) -> Model:
    tp = mesh.shape.get("model", 1)
    pd = cfg.padded(tp)
    nq, nkv, hd, V = pd.num_q_heads, pd.num_kv_heads, pd.head_dim, pd.vocab_size
    d, L, eps = cfg.d_model, cfg.num_layers, cfg.norm_eps
    ds, conv_w, W = cfg.ssm_state, cfg.conv_width, cfg.window
    d_inner = nq * hd
    n_global = len(cfg.global_layers)
    segs = _segments(cfg)
    n_swa = L - n_global

    layer_specs = {
        "ln": Spec((d,), ("embed",), "ones"),
        "attn": attn_specs(d, nq, nkv, hd, cfg.qkv_bias),
        "ssd": _ssd_specs(d, nq, hd, ds, conv_w),
        "ln_attn": Spec((d,), ("embed",), "ones"),
        "ln_ssd": Spec((d,), ("embed",), "ones"),
        "ln2": Spec((d,), ("embed",), "ones"),
        "ffn": glu_specs(d, cfg.d_ff),
    }
    specs = {
        "embed": embed_specs(V, d),
        "g": stacked(layer_specs, n_global),       # global-attention layers
        "swa": stacked(layer_specs, n_swa),        # sliding-window layers
    }

    def _branches_seq(lp, x, window, ssd_state, train: bool, vl=None):
        """One block over a sequence; returns (x, (k, v), ssd_state)."""
        B, S, _ = x.shape
        h = rmsnorm(x, lp["ln"], eps)
        q, k, v = attn_qkv(lp["attn"], h, nq, nkv, hd)
        pos = jnp.arange(S)[None, :]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        if train:
            o = attention_train(q, k, v, causal=True, window=window)
        else:
            o = attention_prefill(q, k, v, causal=True, window=window,
                                  q_block=q_block, k_block=k_block,
                                  kv_valid=vl)
        a_out = o.reshape(B, S, nq * hd) @ lp["attn"]["wo"]
        s_out, ssd_state = _ssd_seq(lp["ssd"], h, ssd_state, chunk, vl)
        mix = 0.5 * (rmsnorm(a_out, lp["ln_attn"], eps)
                     + rmsnorm(s_out, lp["ln_ssd"], eps))
        x = x + shard(mix, "batch", "seq", "embed")
        x = x + shard(glu_apply(lp["ffn"], rmsnorm(x, lp["ln2"], eps)),
                      "batch", "seq", "embed")
        return x, (k, v), ssd_state

    def _branches_step(lp, x, k_l, v_l, ssd_state, lengths, *, ring: bool):
        B = x.shape[0]
        h = rmsnorm(x, lp["ln"], eps)
        q, k, v = attn_qkv(lp["attn"], h, nq, nkv, hd)
        q = rope(q, lengths[:, None], cfg.rope_theta)
        k = rope(k, lengths[:, None], cfg.rope_theta)
        if ring:
            k_l, v_l = ring_cache_update(k_l, v_l, k, v, lengths)
            o = attention_decode_ring(q, k_l, v_l, lengths)
        else:
            k_l, v_l = cache_update(k_l, v_l, k, v, lengths)
            o = attention_decode_auto(q, k_l, v_l, lengths + 1)
        a_out = o.reshape(B, 1, nq * hd) @ lp["attn"]["wo"]
        s_out, ssd_state = _ssd_step(lp["ssd"], h, ssd_state)
        mix = 0.5 * (rmsnorm(a_out, lp["ln_attn"], eps)
                     + rmsnorm(s_out, lp["ln_ssd"], eps))
        x = x + shard(mix, "batch", None, "embed")
        x = x + shard(glu_apply(lp["ffn"], rmsnorm(x, lp["ln2"], eps)),
                      "batch", None, "embed")
        return x, (k_l, v_l), ssd_state

    def _zero_ssd(n: int, B: int):
        return (jnp.zeros((n, B, conv_w - 1, d_inner), DEFAULT_DTYPE),
                jnp.zeros((n, B, nq, ds, hd), jnp.float32))

    def _layer_at(pp, i):
        return jax.tree.map(lambda p: p[i], pp)

    def _seg_slice(pp, lo, n):
        return jax.tree.map(lambda p: p[lo:lo + n], pp)

    # ---------------- train / prefill driver ----------------
    def _run_seq(params, x, train: bool, collect_cache: bool,
                 Smax: int = 0, vl=None):
        B, S, _ = x.shape
        # padded prefill requires the no-wrap ring branch: junk tail slots
        # [vl, S) are exactly the ones decode overwrites before its valid
        # count reaches them. A wrapped ring (S > W) would alias junk onto
        # live slots — the engine caps the length ladder at W (extras
        # ``prompt_pad_cap``) so this cannot be reached from serving.
        assert vl is None or W >= S, "padded prefill needs prompt bucket <= window"
        caches_g: List[Any] = []
        states_g: List[Any] = []
        caches_w: List[Any] = []
        conv_g0, ssd_g0 = _zero_ssd(n_global, B)
        conv_w0, ssd_w0 = _zero_ssd(n_swa, B)
        swa_lo = 0

        def swa_body(x, xs):
            lp, cs, sm = xs
            x, (k, v), (cs, sm) = _branches_seq(lp, x, W, (cs, sm), train,
                                                vl)
            if collect_cache:
                if W >= S:      # no wrap yet: positions p land at slots p
                    pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
                    kw, vw = jnp.pad(k, pad), jnp.pad(v, pad)
                else:           # ring: position p lives at slot p % W
                    kw = jnp.roll(k[:, -W:], S % W, axis=1)
                    vw = jnp.roll(v[:, -W:], S % W, axis=1)
                return x, (kw, vw, cs, sm)
            return x, None

        body = swa_body
        if train and remat != "none":
            body = jax.checkpoint(swa_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)

        for gi in range(n_global):
            lp = _layer_at(params["g"], gi)
            x, (k, v), st = _branches_seq(
                lp, x, 0, (conv_g0[gi], ssd_g0[gi]), train, vl)
            if collect_cache:
                if Smax > S:
                    pad = [(0, 0), (0, Smax - S), (0, 0), (0, 0)]
                    k, v = jnp.pad(k, pad), jnp.pad(v, pad)
                caches_g.append((k, v))
                states_g.append(st)
            n = segs[gi]
            if n:
                seg = _seg_slice(params["swa"], swa_lo, n)
                x, ys = lax.scan(body, x,
                                 (seg, conv_w0[swa_lo:swa_lo + n],
                                  ssd_w0[swa_lo:swa_lo + n]))
                if collect_cache:
                    caches_w.append(ys)
                swa_lo += n
        return x, caches_g, states_g, caches_w

    def loss_fn(params, batch):
        x = embed_tokens(params["embed"], batch["tokens"])
        x, _, _, _ = _run_seq(params, x, train=True, collect_cache=False)
        return chunked_loss(params["embed"], x, batch["labels"], eps)

    def prefill(params, batch, max_len=None):
        x = embed_tokens(params["embed"], batch["tokens"])
        B, S, _ = x.shape
        Smax = max_len or S
        vl = batch.get("lengths")
        x, cg, sg, cw = _run_seq(params, x, train=False, collect_cache=True,
                                 Smax=Smax, vl=vl)
        x_last = x[:, -1:, :] if vl is None else last_valid_slice(x, vl)
        logits = lm_head(params["embed"], x_last, eps)[:, 0]
        cache = {
            "kg": jnp.stack([k for k, _ in cg]),
            "vg": jnp.stack([v for _, v in cg]),
            "kw": jnp.concatenate([y[0] for y in cw], axis=0),
            "vw": jnp.concatenate([y[1] for y in cw], axis=0),
            "conv_g": jnp.stack([st[0] for st in sg]),
            "ssd_g": jnp.stack([st[1] for st in sg]),
            "conv_w": jnp.concatenate([y[2] for y in cw], axis=0),
            "ssd_w": jnp.concatenate([y[3] for y in cw], axis=0),
            "lengths": (jnp.full((B,), S, jnp.int32) if vl is None
                        else vl.astype(jnp.int32)),
        }
        return logits, cache

    def decode_step(params, cache, tokens, lengths):
        x = embed_tokens(params["embed"], tokens)
        kg, vg = [], []
        conv_g, ssd_g = [], []
        swa_lo = 0
        kw_parts, vw_parts, conv_w_parts, ssd_w_parts = [], [], [], []

        def swa_body(x, xs):
            lp, k_l, v_l, cs, sm = xs
            x, (k_l, v_l), (cs, sm) = _branches_step(
                lp, x, k_l, v_l, (cs, sm), lengths, ring=True)
            return x, (k_l, v_l, cs, sm)

        for gi in range(n_global):
            lp = _layer_at(params["g"], gi)
            x, (k_l, v_l), (cs, sm) = _branches_step(
                lp, x, cache["kg"][gi], cache["vg"][gi],
                (cache["conv_g"][gi], cache["ssd_g"][gi]), lengths,
                ring=False)
            kg.append(k_l), vg.append(v_l)
            conv_g.append(cs), ssd_g.append(sm)
            n = segs[gi]
            if n:
                seg = _seg_slice(params["swa"], swa_lo, n)
                sl = slice(swa_lo, swa_lo + n)
                x, (kn, vn, cn, sn) = lax.scan(
                    swa_body, x,
                    (seg, cache["kw"][sl], cache["vw"][sl],
                     cache["conv_w"][sl], cache["ssd_w"][sl]))
                kw_parts.append(kn), vw_parts.append(vn)
                conv_w_parts.append(cn), ssd_w_parts.append(sn)
                swa_lo += n
        logits = lm_head(params["embed"], x, eps)[:, 0]
        new_cache = {
            "kg": jnp.stack(kg), "vg": jnp.stack(vg),
            "conv_g": jnp.stack(conv_g), "ssd_g": jnp.stack(ssd_g),
            "kw": jnp.concatenate(kw_parts, axis=0),
            "vw": jnp.concatenate(vw_parts, axis=0),
            "conv_w": jnp.concatenate(conv_w_parts, axis=0),
            "ssd_w": jnp.concatenate(ssd_w_parts, axis=0),
            "lengths": lengths + 1,
        }
        return logits, new_cache

    def init_cache(batch: int, max_len: int):
        conv_g, ssd_g = _zero_ssd(n_global, batch)
        conv_w, ssd_w = _zero_ssd(n_swa, batch)
        return {
            "kg": jnp.zeros((n_global, batch, max_len, nkv, hd), DEFAULT_DTYPE),
            "vg": jnp.zeros((n_global, batch, max_len, nkv, hd), DEFAULT_DTYPE),
            "kw": jnp.zeros((n_swa, batch, W, nkv, hd), DEFAULT_DTYPE),
            "vw": jnp.zeros((n_swa, batch, W, nkv, hd), DEFAULT_DTYPE),
            "conv_g": conv_g, "ssd_g": ssd_g,
            "conv_w": conv_w, "ssd_w": ssd_w,
            "lengths": jnp.zeros((batch,), jnp.int32),
        }

    def cache_axes(batch: int, max_len: int):
        kv = (None, "batch", None, "kv_heads", None)
        return {
            "kg": kv, "vg": kv, "kw": kv, "vw": kv,
            "conv_g": (None, "batch", None, "heads"),
            "ssd_g": (None, "batch", "heads", None, None),
            "conv_w": (None, "batch", None, "heads"),
            "ssd_w": (None, "batch", "heads", None, None),
            "lengths": ("batch",),
        }

    return Model(
        cfg=cfg,
        init=lambda rng: init_tree(rng, specs),
        param_axes=axes_tree(specs),
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_axes=cache_axes,
        # prompt padding is exact here (masked SSD gates + per-sample conv
        # state), but only while the padded bucket stays within the sliding
        # window — beyond W the ring cache wraps junk onto live slots
        extras={"padded": pd, "segments": segs,
                "prompt_pad": True, "prompt_pad_cap": W},
    )
