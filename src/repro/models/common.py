"""Shared model substrate: parameter specs, norms, RoPE, attention paths.

Three attention implementations (DESIGN.md §5):

* ``attention_train``   — full masked einsum; differentiable; used by the
  train step (seq ≤ 4k, transient S² scores bounded via microbatching).
* ``attention_prefill`` — blocked online-softmax with *causal block skipping*
  (``fori_loop`` with data-dependent trip count); forward-only; used by
  serve_prefill so 32k contexts never materialize S².
* ``attention_decode``  — single-query masked attention against a cache with
  per-sample lengths.

The Pallas kernels in ``repro.kernels`` are the TPU-target hot-path versions
of the latter two, validated against these (and ``ref.py``) oracles. The
serving hot path picks between them through the **attention backend switch**
(:func:`set_attention_backend` / :func:`attention_decode_auto`): when the
backend is ``"pallas"`` and the shapes permit, single-token decode attention
dispatches to the Pallas kernel; otherwise the jnp path below serves as the
fallback (and as the parity oracle — see tests/test_serving_fused.py).
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard

DEFAULT_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

class Spec(NamedTuple):
    """Declarative parameter: shape, logical axes, init kind."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    fan_in: Optional[int] = None
    dtype: Any = DEFAULT_DTYPE


def _init_leaf(key, spec: Spec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan = spec.fan_in or (spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1])
    scale = 1.0 / math.sqrt(max(1, fan))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def init_tree(rng, specs):
    """Instantiate a (nested dict) tree of Specs into parameters."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(specs):
    """Extract the logical-axes tree (same structure as params)."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def stacked(specs, num: int):
    """Prepend a scan (layer) dimension to every Spec in the tree."""
    return jax.tree.map(
        lambda s: Spec((num,) + s.shape, (None,) + s.axes, s.init, s.fan_in, s.dtype),
        specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# norms & rope
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention paths
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: [B,Sq,Hkv,G,D], k: [B,Sk,Hkv,D] -> [B,Hkv,G,Sq,Sk] fp32."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


_NO_WINDOW = 1 << 30


def _effective_window(window) -> jax.Array:
    """window may be a Python int or a traced per-layer scalar; 0 = full."""
    w = jnp.asarray(window, jnp.int32)
    return jnp.where(w > 0, w, _NO_WINDOW)


def attention_train(q, k, v, *, causal: bool = True, window=0,
                    scale: Optional[float] = None) -> jax.Array:
    """Full masked attention (differentiable). q [B,Sq,Hq,D], k/v [B,Sk,Hkv,D]."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = scale or (1.0 / math.sqrt(D))
    qg = q.reshape(B, Sq, Hkv, G, D) * scale
    s = _gqa_scores(qg, k)  # [B,Hkv,G,Sq,Sk]
    Sk = k.shape[1]
    w = _effective_window(window)
    q_pos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    k_pos = jnp.arange(Sk)[None, :]
    mask = (k_pos > q_pos - w)
    if causal:
        mask &= k_pos <= q_pos
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, Hq, D)


def attention_prefill(q, k, v, *, causal: bool = True, window=0,
                      q_block: int = 512, k_block: int = 1024,
                      scale: Optional[float] = None,
                      q_offset=None, kv_valid=None) -> jax.Array:
    """Blocked online-softmax attention with causal/window block skipping.

    Forward-only (uses fori_loop with data-dependent trip counts). Never
    materializes more than a [q_block, k_block] score tile per (B, Hkv, G).

    q_offset: absolute position of q row 0 (may be traced — used by the
    context-parallel path where each shard holds a sequence slice). Defaults
    to suffix alignment (Sk - Sq).

    kv_valid: optional per-sample valid key length [B] — keys at positions
    >= kv_valid[b] are masked out. Used by bucket-padded prefill (the
    serving length ladder) so right-pad junk tokens never contribute
    attention mass; under causal masking real rows already never see the
    later junk keys, so this additionally cleans the junk rows themselves
    and covers the non-causal (encoder) case.
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale or (1.0 / math.sqrt(D))
    q_block = min(q_block, Sq)
    k_block = min(k_block, Sk)
    assert Sq % q_block == 0 and Sk % k_block == 0, (Sq, q_block, Sk, k_block)
    nq = Sq // q_block
    w = _effective_window(window)
    if q_offset is None:
        q_offset = Sk - Sq
    qg = (q.reshape(B, Sq, Hkv, G, D) * scale)

    def q_step(_, qi):
        qb = lax.dynamic_slice_in_dim(qg, qi * q_block, q_block, axis=1)
        q_lo = qi * q_block + q_offset            # absolute pos of first q row
        q_hi = q_lo + q_block - 1
        # block range of k that can be attended by this q block
        nk = Sk // k_block
        k_end = jnp.minimum((q_hi // k_block) + 1, nk) if causal else nk
        k_start = jnp.maximum(0, (q_lo - w + 1) // k_block)

        acc0 = jnp.zeros((B, q_block, Hkv, G, D), jnp.float32)
        m0 = jnp.full((B, Hkv, G, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)

        def k_step(ki, carry):
            acc, m, l = carry
            kb = lax.dynamic_slice_in_dim(k, ki * k_block, k_block, axis=1)
            vb = lax.dynamic_slice_in_dim(v, ki * k_block, k_block, axis=1)
            s = _gqa_scores(qb, kb)  # [B,Hkv,G,qb,kb]
            q_pos = q_lo + jnp.arange(q_block)[:, None]
            k_pos = ki * k_block + jnp.arange(k_block)[None, :]
            mask = (k_pos > q_pos - w)
            if causal:
                mask &= k_pos <= q_pos
            full_mask = mask[None, None, None, :, :]
            if kv_valid is not None:
                vm = k_pos[0] < kv_valid[:, None]          # [B, kb]
                full_mask = full_mask & vm[:, None, None, None, :]
            s = jnp.where(full_mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None]
            acc = acc + jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), vb
                                   ).astype(jnp.float32)
            return acc, m_new, l

        acc, m, l = lax.fori_loop(k_start, k_end, k_step, (acc0, m0, l0))
        safe_l = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return None, (acc / safe_l).astype(q.dtype)

    _, blocks = lax.scan(q_step, None, jnp.arange(nq))   # [nq,B,qb,Hkv,G,D]
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, Hkv, G, D)
    return out.reshape(B, Sq, Hq, D)


def attention_decode(q, k_cache, v_cache, lengths, *, window=0,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-token attention against a cache.

    q: [B,1,Hq,D]; k/v_cache: [B,Smax,Hkv,D]; lengths: [B] number of valid
    positions (the current token is at lengths-1).
    """
    B, _, Hq, D = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale or (1.0 / math.sqrt(D))
    w = _effective_window(window)
    qg = q.reshape(B, 1, Hkv, G, D) * scale
    s = _gqa_scores(qg, k_cache)[:, :, :, 0, :]        # [B,Hkv,G,Sk]
    k_pos = jnp.arange(Smax)[None, :]
    valid = (k_pos < lengths[:, None]) & (k_pos >= lengths[:, None] - w)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with zero valid keys (lengths == 0) attend to nothing, not to a
    # uniform smear over the mask floor — keeps jnp/Pallas parity exact
    p = jnp.where(valid.any(-1)[:, None, None, None], p, 0.0)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, Hq, D)


# ---------------------------------------------------------------------------
# attention kernel backend switch (serving hot path)
# ---------------------------------------------------------------------------

_BACKENDS = ("jnp", "pallas")
_backend = os.environ.get("REPRO_ATTENTION_BACKEND", "jnp")


def set_attention_backend(name: str) -> str:
    """Select the decode-attention implementation (``"jnp"`` | ``"pallas"``)
    and return the previous choice. Read at *trace* time: set it before the
    first call of any jitted step that should use it (the serving engine
    traces its decode step on first dispatch). ``REPRO_ATTENTION_BACKEND``
    seeds the initial value."""
    global _backend
    if name not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}: {name!r}")
    prev = _backend
    _backend = name
    return prev


def get_attention_backend() -> str:
    return _backend


def _pow2_divisor(n: int) -> int:
    return n & -n if n > 0 else 0


def pallas_decode_viable(q_shape, kv_shape, window) -> bool:
    """Static shape gate for Pallas decode-attention dispatch: single query
    token, grouped heads, and a cache length with a usable power-of-two
    k-block tile. ``window`` must be a Python int (per-layer traced windows
    fall back to jnp)."""
    if not isinstance(window, int):
        return False
    B, one, Hq, D = q_shape
    Smax, Hkv = kv_shape[1], kv_shape[2]
    if one != 1 or Hkv == 0 or Hq % Hkv:
        return False
    return _pow2_divisor(Smax) >= 8


def attention_decode_auto(q, k_cache, v_cache, lengths, *, window=0,
                          scale: Optional[float] = None) -> jax.Array:
    """Backend-dispatched single-token decode attention (model layout:
    q [B,1,Hq,D]; k/v_cache [B,Smax,Hkv,D]; lengths [B]).

    Routes to the Pallas kernel when the backend is ``"pallas"`` and the
    static shapes permit; otherwise (or on shape mismatch) falls back to the
    jnp oracle. Off-TPU the kernel runs in interpret mode, so parity tests
    exercise the same dispatch path CI uses."""
    if (_backend == "pallas" and scale is None
            and pallas_decode_viable(q.shape, k_cache.shape, window)):
        from repro.kernels.decode_attention.ops import decode_attention_op
        k_blk = min(256, _pow2_divisor(k_cache.shape[1]))
        return decode_attention_op(
            q, k_cache, v_cache, lengths, window=window, k_blk=k_blk,
            interpret=jax.default_backend() != "tpu")
    return attention_decode(q, k_cache, v_cache, lengths, window=window,
                            scale=scale)


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def attn_specs(d_model: int, nq: int, nkv: int, hd: int, bias: bool) -> Dict[str, Spec]:
    s = {
        "wq": Spec((d_model, nq * hd), ("fsdp", "heads"), fan_in=d_model),
        "wk": Spec((d_model, nkv * hd), ("fsdp", "kv_heads"), fan_in=d_model),
        "wv": Spec((d_model, nkv * hd), ("fsdp", "kv_heads"), fan_in=d_model),
        "wo": Spec((nq * hd, d_model), ("heads", "fsdp"), fan_in=nq * hd),
    }
    if bias:
        s["bq"] = Spec((nq * hd,), ("heads",), init="zeros")
        s["bk"] = Spec((nkv * hd,), ("kv_heads",), init="zeros")
        s["bv"] = Spec((nkv * hd,), ("kv_heads",), init="zeros")
    return s


def attn_qkv(p, x, nq: int, nkv: int, hd: int):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = shard(q.reshape(B, S, nq, hd), "batch", "seq", "heads", None)
    k = shard(k.reshape(B, S, nkv, hd), "batch", "seq", "kv_heads", None)
    v = shard(v.reshape(B, S, nkv, hd), "batch", "seq", "kv_heads", None)
    return q, k, v


def glu_specs(d_model: int, d_ff: int) -> Dict[str, Spec]:
    return {
        "wi": Spec((d_model, d_ff), ("fsdp", "ffn"), fan_in=d_model),
        "wg": Spec((d_model, d_ff), ("fsdp", "ffn"), fan_in=d_model),
        "wo": Spec((d_ff, d_model), ("ffn", "fsdp"), fan_in=d_ff),
    }


def glu_apply(p, x):
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    h = shard(h, "batch", "seq", "ffn")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# embedding / head / loss
# ---------------------------------------------------------------------------

def embed_specs(vocab: int, d_model: int) -> Dict[str, Spec]:
    return {
        "embedding": Spec((vocab, d_model), ("vocab", "fsdp"), fan_in=1),
        "head": Spec((d_model, vocab), ("fsdp", "vocab"), fan_in=d_model),
        "final_norm": Spec((d_model,), ("embed",), init="ones"),
    }


def embed_tokens(p, tokens):
    x = jnp.take(p["embedding"], tokens, axis=0)
    return shard(x, "batch", "seq", "embed")


def lm_head(p, x, norm_eps: float):
    x = rmsnorm(x, p["final_norm"], norm_eps)
    logits = x @ p["head"]
    return shard(logits, "batch", None, "vocab")


def last_valid_slice(x: jax.Array, lengths: jax.Array) -> jax.Array:
    """x: [B,S,d]; lengths: [B] -> [B,1,d], row ``lengths[b]-1`` per sample.

    The bucket-padded prefill path right-pads prompts, so "the last token"
    is per-sample, not position S-1."""
    B, S, d = x.shape
    idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, S - 1)[:, None, None]
    return jnp.take_along_axis(x, jnp.broadcast_to(idx, (B, 1, d)), axis=1)


def chunked_loss(p, x, labels, norm_eps: float, chunk: int = 512) -> jax.Array:
    """Cross-entropy over the vocab, chunked over sequence so full [B,S,V]
    logits are never materialized. x: [B,S,d], labels: [B,S]."""
    B, S, _ = x.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    x = rmsnorm(x, p["final_norm"], norm_eps)

    def step(tot, idx):
        xb = lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=1)
        yb = lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        logits = (xb @ p["head"]).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    total, _ = lax.scan(step, jnp.float32(0.0), jnp.arange(S // chunk))
    return total / (B * S)


# ---------------------------------------------------------------------------
# KV cache helpers (per-sample positions -> continuous batching friendly)
# ---------------------------------------------------------------------------

def cache_update(k_cache, v_cache, k_new, v_new, lengths):
    """Write one new K/V row per sample at its own position.

    k_cache/v_cache: [B,Smax,Hkv,D]; k_new/v_new: [B,1,Hkv,D]; lengths: [B]
    (position to write, i.e. current length before this token).
    """
    def write(c, row, pos):
        return lax.dynamic_update_slice(c, row, (pos, 0, 0))
    k_cache = jax.vmap(write)(k_cache, k_new, lengths)
    v_cache = jax.vmap(write)(v_cache, v_new, lengths)
    return k_cache, v_cache


def ring_cache_update(k_cache, v_cache, k_new, v_new, lengths):
    """Sliding-window ring buffer: write at position % window. Attention is
    permutation-invariant over KV rows (RoPE is applied at write time), so
    circular order is fine — only the valid count matters."""
    W = k_cache.shape[1]
    return cache_update(k_cache, v_cache, k_new, v_new, lengths % W)


def attention_decode_ring(q, k_cache, v_cache, lengths, *,
                          scale: Optional[float] = None) -> jax.Array:
    """Decode attention against a window-sized ring cache.

    All slots are valid once the ring has wrapped; before that, only the
    first ``lengths+1`` slots hold data. q: [B,1,Hq,D]; caches [B,W,Hkv,D];
    lengths: [B] tokens seen BEFORE this one (current was just written)."""
    W = k_cache.shape[1]
    count = jnp.minimum(lengths + 1, W)
    return attention_decode_auto(q, k_cache, v_cache, count, window=0,
                                 scale=scale)
