"""CLI: convert repro observability documents to Chrome ``trace_event``
JSON (about:tracing / Perfetto) or CSV (DESIGN.md §13/§15).

    PYTHONPATH=src python -m repro.obs.export trace.json -o chrome.json
    PYTHONPATH=src python -m repro.obs.export --mode timeseries ts.json \
        -o counters.json
    PYTHONPATH=src python -m repro.obs.export --mode audit audit.json \
        --format csv -o decisions.csv

Input documents are dispatched on their ``schema`` field (``--mode``
asserts the expectation):

* ``repro.trace/v1`` — spans become duration events (``ph: "X"``) on the
  track of their trace id; instant events become ``ph: "i"``. Fault-path
  events get a *distinct* instant scope so the recovery timeline stands
  out in Perfetto: global fault/alert events (crashes, detections,
  recoveries) render process-scoped (``s: "p"``), per-query fault events
  (retry, hedge) thread-scoped with their trace.
* ``repro.timeseries/v1`` — each series becomes a Chrome *counter track*
  (``ph: "C"``), and monitor alerts become global instant events; CSV is
  ``series,t,value`` rows.
* ``repro.audit/v1`` — each decision becomes an instant event on its
  actor's track; CSV is ``seq,t,actor,action,model,evidence`` rows.

Timestamps convert from seconds to microseconds (the trace_event
contract). The conversion is a pure function of the input, so exports of
byte-identical documents are byte-identical too.
"""

from __future__ import annotations

import argparse
import csv
import io
import json
import sys
from typing import Any, Dict, List

from repro.obs.audit import AUDIT_SCHEMA
from repro.obs.timeseries import TIMESERIES_SCHEMA
from repro.obs.tracer import TRACE_SCHEMA

# components whose instant events are fleet-wide, not per-query: render
# process-scoped so Perfetto draws them across every track
_GLOBAL_EVENT_COMPONENTS = {"faults", "obs.monitor"}
# per-query fault-path event names (frontend.fault / lm.fault components)
_FAULT_EVENT_NAMES = {"retry", "retry_exhausted", "hedge"}


def _event_scope(span: Dict[str, Any]) -> str:
    """Instant-event scope: fault/alert events get a distinct scope from
    ordinary per-query instants (cache probes, admission verdicts) so the
    PR 9 recovery timeline is visible at a glance."""
    comp = span.get("component", "")
    name = span.get("name", "")
    if comp in _GLOBAL_EVENT_COMPONENTS:
        return "g"
    if name.startswith("fault.") or name in _FAULT_EVENT_NAMES:
        return "p"
    return "t"


def chrome_trace(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Convert a ``repro.trace/v1`` document to a Chrome trace object."""
    if doc.get("schema") != TRACE_SCHEMA:
        raise ValueError(
            f"not a {TRACE_SCHEMA} document: schema={doc.get('schema')!r}")
    events: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
         "args": {"name": "repro serving"}},
    ]
    for s in doc.get("spans", []):
        args = dict(s.get("attrs") or {})
        if s.get("budget_s") is not None:
            args["budget_s"] = s["budget_s"]
        base = {
            "name": s["name"],
            "cat": s["component"],
            "pid": 1,
            # one track per trace: a query's whole lifecycle reads as one
            # lane in the flamegraph (trace 0 holds global events)
            "tid": s["trace_id"],
            "ts": s["start"] * 1e6,
            "args": args,
        }
        if s.get("kind") == "event":
            events.append({**base, "ph": "i", "s": _event_scope(s)})
        else:
            end = s["end"] if s.get("end") is not None else s["start"]
            events.append({**base, "ph": "X",
                           "dur": max(0.0, (end - s["start"]) * 1e6)})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": doc["schema"],
                          "sample_rate": doc.get("sample_rate"),
                          "seed": doc.get("seed"),
                          "dropped": doc.get("dropped")}}


def chrome_timeseries(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Convert a ``repro.timeseries/v1`` document to Chrome counter
    tracks: one ``ph: "C"`` event per sample point, plus process-scoped
    instants for the monitor's alert transitions."""
    if doc.get("schema") != TIMESERIES_SCHEMA:
        raise ValueError(f"not a {TIMESERIES_SCHEMA} document: "
                         f"schema={doc.get('schema')!r}")
    events: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
         "args": {"name": "repro fleet telemetry"}},
    ]
    for name, series in sorted(doc.get("series", {}).items()):
        for t, v in series.get("points", []):
            events.append({"ph": "C", "name": name, "cat": "timeseries",
                           "pid": 1, "tid": 0, "ts": t * 1e6,
                           "args": {"value": v}})
    for ev in doc.get("events", []):
        events.append({"ph": "i", "s": "p",
                       "name": f"alert.{ev['kind']}", "cat": "obs.monitor",
                       "pid": 1, "tid": 0, "ts": ev["t"] * 1e6,
                       "args": {"alert": ev.get("alert"),
                                **(ev.get("evidence") or {})}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": doc["schema"],
                          "interval_s": doc.get("interval_s"),
                          "samples": doc.get("samples")}}


def chrome_audit(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Convert a ``repro.audit/v1`` document to instant events, one track
    per decision actor (autoscaler / admission / router / faults)."""
    if doc.get("schema") != AUDIT_SCHEMA:
        raise ValueError(f"not a {AUDIT_SCHEMA} document: "
                         f"schema={doc.get('schema')!r}")
    records = doc.get("records", [])
    actors = sorted({r["actor"] for r in records})
    tids = {a: i + 1 for i, a in enumerate(actors)}
    events: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
         "args": {"name": "repro control-plane decisions"}},
    ]
    for a in actors:
        events.append({"ph": "M", "name": "thread_name", "pid": 1,
                       "tid": tids[a], "args": {"name": a}})
    for r in records:
        args: Dict[str, Any] = {"seq": r["seq"]}
        if r.get("model") is not None:
            args["model"] = r["model"]
        args.update(r.get("evidence") or {})
        events.append({"ph": "i", "s": "t",
                       "name": f"{r['actor']}.{r['action']}",
                       "cat": "audit", "pid": 1, "tid": tids[r["actor"]],
                       "ts": r["t"] * 1e6, "args": args})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": doc["schema"],
                          "total": doc.get("total"),
                          "dropped": doc.get("dropped")}}


def csv_timeseries(doc: Dict[str, Any]) -> str:
    """``series,t,value`` rows, series sorted then time-ordered."""
    if doc.get("schema") != TIMESERIES_SCHEMA:
        raise ValueError(f"not a {TIMESERIES_SCHEMA} document: "
                         f"schema={doc.get('schema')!r}")
    buf = io.StringIO()
    w = csv.writer(buf, lineterminator="\n")
    w.writerow(["series", "t", "value"])
    for name, series in sorted(doc.get("series", {}).items()):
        for t, v in series.get("points", []):
            w.writerow([name, repr(t), repr(v)])
    return buf.getvalue()


def csv_audit(doc: Dict[str, Any]) -> str:
    """``seq,t,actor,action,model,evidence`` rows (evidence as JSON)."""
    if doc.get("schema") != AUDIT_SCHEMA:
        raise ValueError(f"not a {AUDIT_SCHEMA} document: "
                         f"schema={doc.get('schema')!r}")
    buf = io.StringIO()
    w = csv.writer(buf, lineterminator="\n")
    w.writerow(["seq", "t", "actor", "action", "model", "evidence"])
    for r in doc.get("records", []):
        w.writerow([r["seq"], repr(r["t"]), r["actor"], r["action"],
                    r.get("model") or "",
                    json.dumps(r.get("evidence") or {}, sort_keys=True)])
    return buf.getvalue()


_MODES = {
    "spans": TRACE_SCHEMA,
    "timeseries": TIMESERIES_SCHEMA,
    "audit": AUDIT_SCHEMA,
}


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Convert a repro observability document (trace span "
                    "log, fleet time-series, or decision audit log) to "
                    "Chrome trace_event JSON or CSV.")
    p.add_argument("trace", help="path to a repro.trace/v1, "
                                 "repro.timeseries/v1, or repro.audit/v1 "
                                 "JSON file (the --trace-out / "
                                 "--timeseries-out / --audit-out of the "
                                 "run CLIs)")
    p.add_argument("--mode", default="auto",
                   choices=("auto", "spans", "timeseries", "audit"),
                   help="expected document kind (default: dispatch on the "
                        "schema field)")
    p.add_argument("--format", default="chrome", choices=("chrome", "csv"),
                   help="output format (csv: timeseries/audit only)")
    p.add_argument("-o", "--out", default=None,
                   help="write the converted output here instead of stdout")
    return p


def main(argv=None) -> int:
    parser = build_parser()
    args = parser.parse_args(argv)
    with open(args.trace) as f:
        doc = json.load(f)
    schema = doc.get("schema") if isinstance(doc, dict) else None
    if args.mode != "auto" and schema != _MODES[args.mode]:
        parser.error(f"--mode {args.mode} expects {_MODES[args.mode]!r}, "
                     f"got schema={schema!r}")
    try:
        if args.format == "csv":
            if schema == TIMESERIES_SCHEMA:
                text = csv_timeseries(doc)
            elif schema == AUDIT_SCHEMA:
                text = csv_audit(doc)
            else:
                parser.error("--format csv supports timeseries/audit "
                             f"documents, got schema={schema!r}")
        else:
            if schema == TRACE_SCHEMA:
                out = chrome_trace(doc)
            elif schema == TIMESERIES_SCHEMA:
                out = chrome_timeseries(doc)
            elif schema == AUDIT_SCHEMA:
                out = chrome_audit(doc)
            else:
                raise ValueError(f"unknown schema {schema!r}; expected one "
                                 f"of {sorted(_MODES.values())}")
            text = json.dumps(out, sort_keys=True, indent=2) + "\n"
    except ValueError as e:
        parser.error(str(e))
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
