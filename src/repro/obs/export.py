"""CLI: convert a ``repro.trace/v1`` span log to Chrome ``trace_event``
JSON, loadable in ``about:tracing`` / Perfetto (DESIGN.md §13).

    PYTHONPATH=src python -m repro.obs.export trace.json -o chrome.json

Every completed span becomes a duration event (``ph: "X"``) on the track
of its trace id, instant events become ``ph: "i"``, and timestamps are
converted from seconds (the tracer's clock units) to microseconds (the
trace_event contract). The conversion is a pure function of the input, so
exports of byte-identical span logs are byte-identical too.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

from repro.obs.tracer import TRACE_SCHEMA


def chrome_trace(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Convert a ``repro.trace/v1`` document to a Chrome trace object."""
    if doc.get("schema") != TRACE_SCHEMA:
        raise ValueError(
            f"not a {TRACE_SCHEMA} document: schema={doc.get('schema')!r}")
    events: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
         "args": {"name": "repro serving"}},
    ]
    for s in doc.get("spans", []):
        args = dict(s.get("attrs") or {})
        if s.get("budget_s") is not None:
            args["budget_s"] = s["budget_s"]
        base = {
            "name": s["name"],
            "cat": s["component"],
            "pid": 1,
            # one track per trace: a query's whole lifecycle reads as one
            # lane in the flamegraph (trace 0 holds global events)
            "tid": s["trace_id"],
            "ts": s["start"] * 1e6,
            "args": args,
        }
        if s.get("kind") == "event":
            events.append({**base, "ph": "i", "s": "t"})
        else:
            end = s["end"] if s.get("end") is not None else s["start"]
            events.append({**base, "ph": "X",
                           "dur": max(0.0, (end - s["start"]) * 1e6)})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": doc["schema"],
                          "sample_rate": doc.get("sample_rate"),
                          "seed": doc.get("seed"),
                          "dropped": doc.get("dropped")}}


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Convert a repro.trace/v1 span log to Chrome "
                    "trace_event JSON (about:tracing / Perfetto).")
    p.add_argument("trace", help="path to a repro.trace/v1 JSON file "
                                 "(--trace-out of the run CLIs)")
    p.add_argument("-o", "--out", default=None,
                   help="write the Chrome trace here instead of stdout")
    return p


def main(argv=None) -> int:
    parser = build_parser()
    args = parser.parse_args(argv)
    with open(args.trace) as f:
        doc = json.load(f)
    try:
        out = chrome_trace(doc)
    except ValueError as e:
        parser.error(str(e))
    text = json.dumps(out, sort_keys=True, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
