"""SLO burn-rate monitor: multiwindow alerting over windowed attainment
(DESIGN.md §15).

The classic SRE recipe adapted to the serving fleet: the *error budget* is
``1 - objective`` (objective = the target SLO attainment, e.g. 0.95), the
windowed *error rate* is the fraction of finished queries in the window
that blew their deadline or were shed, and the *burn rate* is error rate
over budget — burn 1.0 consumes the budget exactly at quota. An alert
fires only when **both** a fast and a slow window burn above the
threshold: the fast window gives quick detection and quick resolution, the
slow window suppresses one-batch blips. Fire and resolve are deterministic
events on the sampler's tick boundaries — a pure function of the seeded
run, recorded in the ``repro.timeseries/v1`` document (and mirrored into
the span log as ``alert.fire`` / ``alert.resolve`` global events when
tracing is on).

The monitor is read-only: it samples the stack's ``MetricsRegistry``
counters (completed / violations / shed) and never mutates them, so an
observed run stays byte-identical to an unobserved one.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core import metrics as M


@dataclass(frozen=True)
class MonitorConfig:
    objective: float = 0.95         # target SLO attainment
    fast_window: float = 0.25       # quick detect / quick resolve (s)
    slow_window: float = 0.75       # blip suppression (s)
    burn_threshold: float = 2.0     # fire when both windows burn above this

    @property
    def budget(self) -> float:
        return max(1.0 - self.objective, 1e-9)


class BurnRateMonitor:
    """Multiwindow burn-rate alerting for one serving stack.

    ``observe(now)`` snapshots the counters, computes both windowed burn
    rates, steps the fire/resolve state machine, and returns the alert
    transitions (usually none). The latest gauges are left in ``gauges``
    for the sampler to record as series."""

    def __init__(self, cfg: Optional[MonitorConfig] = None, *,
                 name: str = "slo_burn"):
        self.cfg = cfg if cfg is not None else MonitorConfig()
        self.name = name
        self.metrics = None
        # (t, completed, violations, shed) — windowed deltas read off this
        self._snaps: deque = deque()
        self.active = False
        self.fired = 0
        self.resolved = 0
        self.gauges: Dict[str, float] = {}

    def bind(self, metrics) -> None:
        self.metrics = metrics

    # ------------------------------------------------------------------
    def _window_error(self, now: float, window: float) -> float:
        """Error rate over the trailing window: (violations + sheds) /
        (finished + sheds), from the newest snapshot at or before
        ``now - window`` (or the oldest available early in the run)."""
        newest = self._snaps[-1]
        base = self._snaps[0]
        cutoff = now - window
        for snap in self._snaps:
            if snap[0] <= cutoff + 1e-12:
                base = snap
            else:
                break
        d_done = newest[1] - base[1]
        d_viol = newest[2] - base[2]
        d_shed = newest[3] - base[3]
        finished = d_done + d_shed
        if finished <= 0:
            return 0.0
        return (d_viol + d_shed) / finished

    def observe(self, now: float) -> List[Dict[str, Any]]:
        """One monitoring step at ``now``; returns fire/resolve events."""
        if self.metrics is None:
            return []
        cfg = self.cfg
        m = self.metrics
        self._snaps.append((now, m.counter(M.QUERIES_COMPLETED),
                            m.counter(M.SLO_VIOLATIONS),
                            m.counter(M.QUERIES_SHED)))
        # keep one snapshot beyond the slow window so the windowed delta
        # always has a base point
        while (len(self._snaps) > 2
               and self._snaps[1][0] <= now - cfg.slow_window - 1e-12):
            self._snaps.popleft()
        err_fast = self._window_error(now, cfg.fast_window)
        err_slow = self._window_error(now, cfg.slow_window)
        burn_fast = err_fast / cfg.budget
        burn_slow = err_slow / cfg.budget
        events: List[Dict[str, Any]] = []
        evidence = {
            "burn_fast": burn_fast, "burn_slow": burn_slow,
            "error_fast": err_fast, "error_slow": err_slow,
            "threshold": cfg.burn_threshold, "budget": cfg.budget,
            "fast_window_s": cfg.fast_window,
            "slow_window_s": cfg.slow_window,
        }
        if (not self.active and burn_fast > cfg.burn_threshold
                and burn_slow > cfg.burn_threshold):
            self.active = True
            self.fired += 1
            events.append({"t": now, "kind": "fire", "alert": self.name,
                           "evidence": evidence})
        elif (self.active and burn_fast <= cfg.burn_threshold
                and burn_slow <= cfg.burn_threshold):
            self.active = False
            self.resolved += 1
            events.append({"t": now, "kind": "resolve", "alert": self.name,
                           "evidence": evidence})
        self.gauges = {
            "slo.attainment_fast": 1.0 - err_fast,
            "slo.attainment_slow": 1.0 - err_slow,
            "slo.burn_fast": burn_fast,
            "slo.burn_slow": burn_slow,
            "slo.alert_active": 1.0 if self.active else 0.0,
        }
        return events

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        return {
            "alert": self.name,
            "objective": self.cfg.objective,
            "fast_window_s": self.cfg.fast_window,
            "slow_window_s": self.cfg.slow_window,
            "burn_threshold": self.cfg.burn_threshold,
            "fired": self.fired,
            "resolved": self.resolved,
            "active": self.active,
        }
