"""Fleet time-series telemetry: a clock-agnostic, seed-deterministic
sampler for the control plane's vital signs (DESIGN.md §15).

The run reports are end-of-run aggregates and the span log is per-query;
neither shows the fleet *evolve* — why the autoscaler grew at t=12.4s, when
the cache hit rate collapsed, how deep the flash-crowd backlog got before
admission started shedding. ``FleetSampler`` closes that gap: at a fixed
interval on the driving loop's virtual clock it polls registered *probes*
(stateful callables owned by the serving stacks) and appends each returned
gauge into a bounded per-series ring buffer.

Design rules, mirroring ``core.metrics`` / ``obs.tracer``:

* **Clock-agnostic** — the sampler never reads time. The drive loop calls
  ``sample_until(now)`` and samples are stamped at exact interval
  boundaries ``k * interval`` (computed multiplicatively, so a
  float-accumulated drive clock cannot skew the stamps).
* **Bounded memory** — each series keeps the newest ``capacity`` points;
  overwritten points are counted in ``dropped``, never silently.
* **Deterministic** — everything sampled is a pure function of the seeded
  run, and the serialized document sorts its keys, so two identical runs
  emit byte-identical ``repro.timeseries/v1`` JSON.

An optional ``BurnRateMonitor`` (obs.monitor) is consulted at every sample:
its windowed attainment/burn gauges join the series and its fire/resolve
alerts land in the document's ``events`` (and, when a tracer is bound, in
the span log as global events).
"""

from __future__ import annotations

import json
from typing import Any, Callable, Dict, List, Optional

TIMESERIES_SCHEMA = "repro.timeseries/v1"

# probe signature: (now, dt) -> {series_name: float gauge}
Probe = Callable[[float, float], Dict[str, float]]


class SeriesRing:
    """Bounded ring of ``[t, value]`` points for one series, oldest first
    when read; the overwritten count is reported as ``dropped``."""

    __slots__ = ("capacity", "_buf", "_n")

    def __init__(self, capacity: int):
        assert capacity > 0
        self.capacity = capacity
        self._buf: List[Optional[List[float]]] = [None] * capacity
        self._n = 0                     # total points ever appended

    def append(self, t: float, value: float) -> None:
        self._buf[self._n % self.capacity] = [float(t), float(value)]
        self._n += 1

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def total(self) -> int:
        return self._n

    @property
    def dropped(self) -> int:
        return max(0, self._n - self.capacity)

    def points(self) -> List[List[float]]:
        """Retained points, oldest first."""
        if self._n <= self.capacity:
            return list(self._buf[: self._n])        # type: ignore[arg-type]
        h = self._n % self.capacity
        return self._buf[h:] + self._buf[:h]         # type: ignore[operator]


class FleetSampler:
    """Interval sampler over registered probes.

    The driving loop owns the timeline: it calls ``sample_until(now)``
    after advancing the clock, and the sampler emits one snapshot per
    elapsed interval boundary. Probes are registered by the stack being
    observed (``Clipper.timeseries_probe``, ``LMServer.timeseries_probe``,
    ``PipelineExecutor.timeseries_probe``); each returns a flat
    ``{series: gauge}`` dict for the current instant. A probe may grow the
    series set mid-run (e.g. a new ladder rung) — new series simply start
    at their first sample."""

    def __init__(self, *, interval: float, capacity: int = 4096,
                 monitor=None):
        assert interval > 0
        self.interval = float(interval)
        self.capacity = capacity
        self.monitor = monitor
        self.tracer = None
        self._probes: List[Probe] = []
        self._series: Dict[str, SeriesRing] = {}
        self._k = 0                     # boundaries emitted so far
        self.samples = 0
        self.events: List[Dict[str, Any]] = []

    # -- wiring ---------------------------------------------------------
    def add_probe(self, probe: Probe) -> None:
        self._probes.append(probe)

    def bind(self, *, metrics=None, tracer=None) -> None:
        """Late-bind the run's registries: the monitor needs the stack's
        ``MetricsRegistry`` (which exists only once the stack is built) and
        alert events mirror into the span log when a tracer is active."""
        if tracer is not None:
            self.tracer = tracer
        if self.monitor is not None and metrics is not None:
            self.monitor.bind(metrics)

    # -- sampling -------------------------------------------------------
    def record(self, name: str, t: float, value: float) -> None:
        ring = self._series.get(name)
        if ring is None:
            ring = self._series[name] = SeriesRing(self.capacity)
        ring.append(t, value)

    def sample(self, t: float) -> None:
        """Take one snapshot stamped ``t``: poll every probe, then the
        monitor (whose gauges + alert transitions ride along)."""
        self.samples += 1
        for probe in self._probes:
            vals = probe(t, self.interval)
            for name in sorted(vals):
                self.record(name, t, vals[name])
        if self.monitor is not None:
            for ev in self.monitor.observe(t):
                self.events.append(ev)
                if self.tracer is not None:
                    self.tracer.global_event(
                        f"alert.{ev['kind']}", "obs.monitor", t,
                        attrs={"alert": ev["alert"], **ev["evidence"]})
            for name in sorted(self.monitor.gauges):
                self.record(name, t, self.monitor.gauges[name])

    def sample_until(self, now: float) -> None:
        """Emit a snapshot at every interval boundary <= ``now``. Stamps
        are exact multiples of the interval (tolerating the drive loop's
        float-accumulated clock by a nanosecond-scale epsilon)."""
        while (self._k + 1) * self.interval <= now + 1e-9:
            self._k += 1
            self.sample(self._k * self.interval)

    # -- reading --------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """The ``repro.timeseries/v1`` document."""
        return {
            "schema": TIMESERIES_SCHEMA,
            "interval_s": self.interval,
            "capacity": self.capacity,
            "samples": self.samples,
            "series": {
                name: {"points": ring.points(), "total": ring.total,
                       "dropped": ring.dropped}
                for name, ring in sorted(self._series.items())
            },
            "events": list(self.events),
            "monitor": (self.monitor.summary()
                        if self.monitor is not None else None),
        }

    def to_json(self) -> str:
        """Stable JSON rendering — byte-identical for identical runs."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=2)
