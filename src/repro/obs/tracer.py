"""Clock-agnostic, seed-deterministic span tracer (DESIGN.md §13).

Clipper's evaluation is *measured* behaviour, but an aggregate report can
only say that p99 degraded — not where the deadline went. This module is
the per-query answer: a ``Tracer`` records ``Span``s for every phase of a
query's lifecycle (cache probe, admission, queue wait, batch service,
straggler hold, pipeline stages, LM prefill/decode) into a bounded
ring-buffer ``SpanLog``, and accumulates an exact *latency attribution* —
the fraction of end-to-end latency spent in each component — that both
serving stacks surface in their ``repro.metrics/v1`` reports.

Design rules, mirroring ``core.metrics``:

* **Clock-agnostic** — the tracer never reads time; every call takes an
  explicit timestamp from whatever owns the timeline (``VirtualClock`` in
  calibrated simulation, wall clock otherwise). Under a virtual clock the
  span log and the attribution are *exact* and byte-identical per seed.
* **Head-based, seed-deterministic sampling** — whether a trace is
  recorded is decided once at its root from ``hash(seed, trace_id)``, so
  a sample rate < 1 keeps whole traces (never orphan child spans) and two
  runs of the same seed sample the identical subset.
* **Bounded memory** — the span log is a ring buffer: the newest
  ``capacity`` completed spans are retained, the overwritten count is
  reported as ``dropped`` (never silently).

The serialized form is the ``repro.trace/v1`` schema (``Tracer.to_json``),
convertible to Chrome ``trace_event`` JSON by ``python -m repro.obs.export``
for flamegraph inspection in ``about:tracing`` / Perfetto.
"""

from __future__ import annotations

import itertools
import json
from typing import Any, Dict, List, Optional

TRACE_SCHEMA = "repro.trace/v1"

_MASK = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    """Deterministic 64-bit mixer (SplitMix64 finalizer) — the sampling
    hash, chosen for platform-independent integer arithmetic."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK
    return (x ^ (x >> 31)) & _MASK


def sample_decision(seed: int, trace_id: int, rate: float) -> bool:
    """Head-based sampling decision: a pure function of (seed, trace_id),
    uniform over traces at the given rate."""
    if rate >= 1.0:
        return True
    if rate <= 0.0:
        return False
    u = _splitmix64((seed & _MASK) ^ _splitmix64(trace_id)) / float(1 << 64)
    return u < rate


class Span:
    """One timed interval (or instant event) of a traced query.

    ``budget_s`` is the span's share of the query's deadline budget where
    one is defined (the SLO for roots, the planner's stage share for
    pipeline stages, the AIMD controller's latency budget for batch
    service, the prefill/decode SLO split for the LM engine) — ``None``
    where no budget is carved out."""

    __slots__ = ("span_id", "trace_id", "parent_id", "name", "component",
                 "start", "end", "kind", "budget_s", "attrs")

    def __init__(self, span_id: int, trace_id: int, parent_id: Optional[int],
                 name: str, component: str, start: float,
                 end: Optional[float] = None, kind: str = "span",
                 budget_s: Optional[float] = None,
                 attrs: Optional[Dict[str, Any]] = None):
        self.span_id = span_id
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.name = name
        self.component = component
        self.start = float(start)
        self.end = end
        self.kind = kind
        self.budget_s = budget_s
        self.attrs = attrs

    @property
    def duration(self) -> float:
        return (self.end - self.start) if self.end is not None else 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "span_id": self.span_id,
            "trace_id": self.trace_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "component": self.component,
            "start": self.start,
            "end": self.end,
            "kind": self.kind,
            "budget_s": self.budget_s,
            "attrs": self.attrs or {},
        }


class SpanLog:
    """Bounded ring buffer of completed spans.

    Spans are appended in completion order (deterministic under a virtual
    clock). When full, the oldest span is overwritten and counted in
    ``dropped`` — memory stays bounded no matter how long the run."""

    def __init__(self, capacity: int = 1 << 16):
        assert capacity > 0
        self.capacity = capacity
        self._buf: List[Optional[Span]] = [None] * capacity
        self._n = 0                     # total spans ever appended

    def append(self, span: Span) -> None:
        self._buf[self._n % self.capacity] = span
        self._n += 1

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def total(self) -> int:
        return self._n

    @property
    def dropped(self) -> int:
        return max(0, self._n - self.capacity)

    def spans(self) -> List[Span]:
        """Retained spans, oldest first."""
        if self._n <= self.capacity:
            return [s for s in self._buf[:self._n]]
        h = self._n % self.capacity
        return self._buf[h:] + self._buf[:h]        # type: ignore[return-value]


class Tracer:
    """Per-query span recording + exact latency attribution.

    All methods tolerate ``parent=None`` (an unsampled trace) by doing
    nothing and propagating ``None``, so instrumentation sites only guard
    on ``tracer is not None`` once, at trace start."""

    def __init__(self, *, sample_rate: float = 1.0, seed: int = 0,
                 capacity: int = 1 << 16):
        assert 0.0 <= sample_rate <= 1.0
        self.sample_rate = sample_rate
        self.seed = seed
        self.log = SpanLog(capacity)
        self._sids = itertools.count(1)
        self._tids = itertools.count(1)
        self.traces = 0                 # traces started (incl. unsampled)
        self.sampled = 0
        # exact attribution accumulators (completed, attributed traces)
        self._attr_seconds: Dict[str, float] = {}
        self._attr_latency = 0.0
        self._attr_queries = 0

    # -- span lifecycle -------------------------------------------------
    def start_trace(self, name: str, component: str, t: float, *,
                    budget_s: Optional[float] = None,
                    attrs: Optional[Dict[str, Any]] = None) -> Optional[Span]:
        """Open a root span; returns ``None`` when the trace is not sampled
        (the id is still consumed, so later sampling decisions never shift)."""
        tid = next(self._tids)
        self.traces += 1
        if not sample_decision(self.seed, tid, self.sample_rate):
            return None
        self.sampled += 1
        return Span(next(self._sids), tid, None, name, component, t,
                    kind="span", budget_s=budget_s,
                    attrs=dict(attrs) if attrs else {})

    def start_span(self, parent: Optional[Span], name: str, component: str,
                   t: float, *, budget_s: Optional[float] = None,
                   attrs: Optional[Dict[str, Any]] = None) -> Optional[Span]:
        if parent is None:
            return None
        return Span(next(self._sids), parent.trace_id, parent.span_id,
                    name, component, t, kind="span", budget_s=budget_s,
                    attrs=dict(attrs) if attrs else None)

    def end_span(self, span: Optional[Span], t: float,
                 **attrs: Any) -> None:
        """Close a span (appends it to the log). Extra attrs merge in."""
        if span is None:
            return
        span.end = float(t)
        if attrs:
            span.attrs = {**(span.attrs or {}), **attrs}
        self.log.append(span)

    def add_span(self, parent: Optional[Span], name: str, component: str,
                 start: float, end: float, *,
                 budget_s: Optional[float] = None,
                 attrs: Optional[Dict[str, Any]] = None) -> Optional[Span]:
        """Record a fully-known (already completed) child span."""
        s = self.start_span(parent, name, component, start,
                            budget_s=budget_s, attrs=attrs)
        if s is not None:
            s.end = float(end)
            self.log.append(s)
        return s

    def event(self, parent: Optional[Span], name: str, component: str,
              t: float, attrs: Optional[Dict[str, Any]] = None) -> None:
        """Instant event under a trace (cache hit/miss, admission verdict,
        deadline firing)."""
        if parent is None:
            return
        self.log.append(Span(next(self._sids), parent.trace_id,
                             parent.span_id, name, component, t, end=float(t),
                             kind="event",
                             attrs=dict(attrs) if attrs else None))

    def global_event(self, name: str, component: str, t: float,
                     attrs: Optional[Dict[str, Any]] = None) -> None:
        """Instant event outside any trace (batch dispatch, prefill
        compile) — trace id 0 in the log."""
        self.log.append(Span(next(self._sids), 0, None, name, component, t,
                             end=float(t), kind="event",
                             attrs=dict(attrs) if attrs else None))

    def end_trace(self, root: Optional[Span], t: float, *,
                  attribution: Optional[Dict[str, float]] = None,
                  status: str = "ok",
                  attrs: Optional[Dict[str, Any]] = None) -> None:
        """Close a root span. ``attribution`` maps component -> exact
        seconds of the query's end-to-end latency; it is stored on the root
        span and accumulated into the run-level ``latency_attribution``
        (fractions summing to 1 for every attributed query)."""
        if root is None:
            return
        root.end = float(t)
        a = {**(root.attrs or {}), "status": status}
        if attrs:
            a.update(attrs)
        if attribution is not None:
            a["attribution"] = dict(sorted(attribution.items()))
            latency = root.end - root.start
            self._attr_latency += latency
            self._attr_queries += 1
            for comp, sec in attribution.items():
                self._attr_seconds[comp] = (
                    self._attr_seconds.get(comp, 0.0) + sec)
        root.attrs = a
        self.log.append(root)

    # -- reading --------------------------------------------------------
    def spans(self) -> List[Span]:
        return self.log.spans()

    def attribution_report(self) -> Dict[str, Any]:
        """Run-level latency attribution: for the attributed (completed,
        nonzero-latency) queries, the share of total end-to-end latency
        each component consumed. Fractions sum to 1 exactly (the per-query
        decompositions are exact partitions of each query's latency)."""
        total = self._attr_latency
        return {
            "queries": self._attr_queries,
            "total_latency_s": total,
            "components": {
                comp: {
                    "seconds": sec,
                    "fraction": (sec / total) if total > 0 else 0.0,
                }
                for comp, sec in sorted(self._attr_seconds.items())
            },
        }

    def summary(self) -> Dict[str, Any]:
        return {
            "sample_rate": self.sample_rate,
            "seed": self.seed,
            "traces": self.traces,
            "sampled_traces": self.sampled,
            "spans": len(self.log),
            "spans_total": self.log.total,
            "dropped": self.log.dropped,
            "capacity": self.log.capacity,
        }

    def to_dict(self) -> Dict[str, Any]:
        """The ``repro.trace/v1`` document."""
        return {
            "schema": TRACE_SCHEMA,
            **self.summary(),
            "attribution": self.attribution_report(),
            "spans": [s.to_dict() for s in self.spans()],
        }

    def to_json(self) -> str:
        """Stable JSON rendering — byte-identical for identical runs."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=2)
