"""Control-plane decision audit log: every capacity decision, with the
evidence that justified it (DESIGN.md §15).

The cluster report says *what* happened (replicas added, queries shed);
the audit log says *why*: each autoscaler grow/drain, admission
shed/degrade, router pick, and fault detection/recovery/hedge/retry is
recorded with the decision-time inputs — the λ/E[s]/backlog/expected-delay
numbers the controller actually looked at — so any capacity decision in a
run is explainable after the fact.

Records live in a bounded ring (newest ``capacity`` kept, overwritten
count reported as ``dropped``); per-action counts are exact regardless of
drops, so invariants like "audit grow count == replicas added" hold even
on truncated logs. The serialized form is the ``repro.audit/v1`` document
— sorted keys, byte-identical per seed, like every other artifact.

Recording is opt-in per run (``--audit-out``): with no log attached every
instrumentation site is a single ``is not None`` check — zero per-query
overhead, the PR 6 discipline.
"""

from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional

AUDIT_SCHEMA = "repro.audit/v1"

# known (actor, action) vocabulary — validate warns on novelty, the log
# itself accepts anything (forward compatibility)
ACTIONS = {
    "autoscaler": ("grow", "drain"),
    "admission": ("shed", "degrade"),
    "router": ("pick",),
    "faults": ("detect", "recover", "hedge", "retry"),
}


def _clean(v: Any) -> Any:
    """JSON-safe evidence values: infinities (e.g. expected delay with no
    live replica) become None rather than non-standard ``Infinity``."""
    if isinstance(v, float) and not math.isfinite(v):
        return None
    if isinstance(v, dict):
        return {k: _clean(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_clean(x) for x in v]
    return v


class AuditLog:
    """Bounded ring of control-plane decision records."""

    def __init__(self, capacity: int = 1 << 14):
        assert capacity > 0
        self.capacity = capacity
        self._buf: List[Optional[Dict[str, Any]]] = [None] * capacity
        self._n = 0                     # total records ever appended
        self.counts: Dict[str, int] = {}    # "actor.action" -> exact count

    def record(self, t: float, actor: str, action: str, *,
               model: Optional[str] = None,
               evidence: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        rec = {
            "seq": self._n,
            "t": float(t),
            "actor": actor,
            "action": action,
            "model": model,
            "evidence": _clean(evidence) if evidence else {},
        }
        self._buf[self._n % self.capacity] = rec
        self._n += 1
        key = f"{actor}.{action}"
        self.counts[key] = self.counts.get(key, 0) + 1
        return rec

    # -- reading --------------------------------------------------------
    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def total(self) -> int:
        return self._n

    @property
    def dropped(self) -> int:
        return max(0, self._n - self.capacity)

    def records(self) -> List[Dict[str, Any]]:
        """Retained records, oldest first."""
        if self._n <= self.capacity:
            return list(self._buf[: self._n])        # type: ignore[arg-type]
        h = self._n % self.capacity
        return self._buf[h:] + self._buf[:h]         # type: ignore[operator]

    def count(self, actor: str, action: str) -> int:
        """Exact count for one decision kind (drop-proof)."""
        return self.counts.get(f"{actor}.{action}", 0)

    def summary(self) -> Dict[str, Any]:
        return {
            "total": self._n,
            "dropped": self.dropped,
            "capacity": self.capacity,
            "counts": dict(sorted(self.counts.items())),
        }

    def to_dict(self) -> Dict[str, Any]:
        """The ``repro.audit/v1`` document."""
        return {
            "schema": AUDIT_SCHEMA,
            **self.summary(),
            "records": self.records(),
        }

    def to_json(self) -> str:
        """Stable JSON rendering — byte-identical for identical runs."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=2)
