"""Shared CLI wiring for the fleet-telemetry flags (DESIGN.md §15).

The three run CLIs (``repro.workloads.run``, ``repro.cluster.run``,
``repro.pipeline.run``) expose the same observability surface:

* ``--timeseries-out FILE``   — sample the fleet's vital signs at a fixed
  virtual-clock interval and write the ``repro.timeseries/v1`` document
  (with the SLO burn-rate monitor's alert events riding along);
* ``--timeseries-interval S`` — the sample interval (default: 0.05 s, the
  control tick);
* ``--audit-out FILE``        — record every control-plane decision
  (autoscaler grow/drain, admission shed/degrade, router pick, fault
  detect/recover/hedge/retry) with its decision-time evidence and write
  the ``repro.audit/v1`` document.

Both are off by default; when off, no sampler/audit object exists and
every instrumentation site in the stacks is a single ``is not None``
check — zero per-query overhead (the PR 6 tracing discipline).
"""

from __future__ import annotations

import argparse
from typing import Optional, Tuple

DEFAULT_INTERVAL = 0.05


def add_fleet_args(p: argparse.ArgumentParser, *,
                   default_interval: float = DEFAULT_INTERVAL) -> None:
    """Add the ``--timeseries-out`` / ``--audit-out`` flag group."""
    p.add_argument("--timeseries-out", default=None,
                   help="sample fleet vital signs (repro.obs.timeseries) "
                        "and write the repro.timeseries/v1 document here — "
                        "byte-identical per seed; convert with "
                        "python -m repro.obs.export --mode timeseries")
    p.add_argument("--timeseries-interval", type=float,
                   default=default_interval,
                   help="sample interval in virtual seconds (default "
                        f"{default_interval:g}; only meaningful with "
                        "--timeseries-out)")
    p.add_argument("--audit-out", default=None,
                   help="record control-plane decisions with their "
                        "evidence (repro.obs.audit) and write the "
                        "repro.audit/v1 document here — convert with "
                        "python -m repro.obs.export --mode audit")


def build_fleet(args, parser: argparse.ArgumentParser
                ) -> Tuple[Optional[object], Optional[object]]:
    """(sampler, audit) from parsed args — (None, None) when both flags
    are off, so the run pays nothing for the capability."""
    sampler = None
    audit = None
    if args.timeseries_out:
        if args.timeseries_interval <= 0:
            parser.error("--timeseries-interval must be > 0")
        from repro.obs import BurnRateMonitor, FleetSampler
        sampler = FleetSampler(interval=args.timeseries_interval,
                               monitor=BurnRateMonitor())
    if args.audit_out:
        from repro.obs import AuditLog
        audit = AuditLog()
    return sampler, audit


def write_fleet(args, sampler, audit) -> None:
    """Serialize whichever collectors the flags enabled."""
    if args.timeseries_out and sampler is not None:
        with open(args.timeseries_out, "w") as f:
            f.write(sampler.to_json() + "\n")
    if args.audit_out and audit is not None:
        with open(args.audit_out, "w") as f:
            f.write(audit.to_json() + "\n")
