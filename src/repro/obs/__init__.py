"""Observability layer: deterministic span tracing and deadline-budget
attribution across both serving stacks (DESIGN.md §13).

* ``Tracer`` / ``Span`` / ``SpanLog`` — clock-agnostic span recording with
  head-based seed-deterministic sampling and bounded memory
  (``repro.trace/v1``);
* ``python -m repro.obs.export`` — Chrome ``trace_event`` conversion for
  flamegraph inspection of any seeded run.
"""

from repro.obs.tracer import (TRACE_SCHEMA, Span, SpanLog, Tracer,
                              sample_decision)

__all__ = ["TRACE_SCHEMA", "Span", "SpanLog", "Tracer", "sample_decision"]
