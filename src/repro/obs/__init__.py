"""Observability layer: deterministic span tracing, fleet time-series
telemetry, SLO burn-rate alerting, and the control-plane decision audit
log (DESIGN.md §13/§15).

* ``Tracer`` / ``Span`` / ``SpanLog`` — clock-agnostic span recording with
  head-based seed-deterministic sampling and bounded memory
  (``repro.trace/v1``);
* ``FleetSampler`` / ``SeriesRing`` — interval sampling of the fleet's
  vital signs into bounded per-series rings (``repro.timeseries/v1``);
* ``BurnRateMonitor`` — multiwindow SLO burn-rate alerting with
  deterministic fire/resolve events;
* ``AuditLog`` — every autoscaler/admission/router/fault decision with
  its decision-time evidence (``repro.audit/v1``);
* ``python -m repro.obs.export`` — Chrome ``trace_event`` (and CSV)
  conversion for flamegraph / counter-track inspection of any seeded run.
"""

from repro.obs.audit import AUDIT_SCHEMA, AuditLog
from repro.obs.monitor import BurnRateMonitor, MonitorConfig
from repro.obs.timeseries import TIMESERIES_SCHEMA, FleetSampler, SeriesRing
from repro.obs.tracer import (TRACE_SCHEMA, Span, SpanLog, Tracer,
                              sample_decision)

__all__ = ["TRACE_SCHEMA", "TIMESERIES_SCHEMA", "AUDIT_SCHEMA",
           "Span", "SpanLog", "Tracer", "sample_decision",
           "FleetSampler", "SeriesRing", "BurnRateMonitor", "MonitorConfig",
           "AuditLog"]
