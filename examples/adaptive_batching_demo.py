"""Adaptive batching demo (paper §4.3, Figs 3-4 live).

Measures the real latency profile of two jitted models on this machine,
then shows AIMD discovering each one's maximum SLO-compliant batch size
online — no manual tuning (the paper's core §4.3 claim).

Run:  PYTHONPATH=src python examples/adaptive_batching_demo.py
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import D_FEAT, make_containers, time_batch
from repro.core import AIMDController, MetricsRegistry
from repro.core import metrics as M


def main():
    rng = np.random.default_rng(0)
    fns = make_containers(rng)
    slo = 0.020
    metrics = MetricsRegistry(slo)
    for name in ("linear_svm", "kernel_svm", "big_mlp"):
        fn = fns[name]
        ctrl = AIMDController(slo, additive=4, backoff=0.9)
        history = []
        for step in range(60):
            b = ctrl.max_batch_size
            x = rng.normal(size=(b, D_FEAT)).astype(np.float32)
            lat = time_batch(fn, x, iters=1)
            ctrl.record(b, lat)
            metrics.observe(M.BATCH_SIZE, b, model=name)
            metrics.observe(M.SERVICE, lat, model=name)
            history.append((b, lat))
        bs = [h[0] for h in history]
        svc = metrics.hist(M.SERVICE, model=name)
        print(f"{name:12s}: AIMD converged max batch = {ctrl.max_batch_size:5d} "
              f"(path: {bs[0]} -> {bs[10]} -> {bs[30]} -> {bs[-1]}), "
              f"latency at converged batch = {history[-1][1]*1e3:.1f} ms "
              f"(SLO {slo*1e3:.0f} ms), "
              f"service p95 = {svc.percentile(95)*1e3:.1f} ms")
    print("\nNo per-model tuning: the same controller found each container's "
          "throughput-optimal batch under the latency objective (Fig 4).")


if __name__ == "__main__":
    main()
