"""Flash-crowd autoscaling demo (control plane, DESIGN.md §10).

Replays the same seeded flash-crowd trace twice through the Clipper
frontend — once with replica counts frozen at the steady-state provisioning
(one replica), once with the reactive autoscaler watching the telemetry —
and prints the SLO story side by side, plus the replica excursion the
controller took.

Run:  PYTHONPATH=src python examples/flash_crowd_autoscale.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.cluster import ClusterPlan, cluster_scenario, run_plan


def describe(tag, rep):
    q = rep["queries"]
    print(f"{tag:10s}: attainment={rep['slo']['attainment']:.3f}  "
          f"violations={rep['slo']['violations']:4d}/{q['submitted']}  "
          f"p50={rep['latency_s']['p50']*1e3:7.1f} ms  "
          f"p99={rep['latency_s']['p99']*1e3:7.1f} ms")


def main():
    sc = cluster_scenario("flash_crowd")
    print(f"flash crowd: {sc.rate:.0f} qps baseline, {sc.peak_rate:.0f} qps "
          f"spike, SLO {sc.slo*1e3:.0f} ms, 1 steady-state replica\n")

    fixed = run_plan(ClusterPlan(scenario=sc, autoscale=False))
    describe("fixed", fixed)

    auto = run_plan(ClusterPlan(scenario=sc, autoscale=True))
    describe("autoscaled", auto)

    a = auto["cluster"]["autoscalers"][0]
    print(f"\nreplicas: 1 -> {a['peak_live']} (spike) -> {a['live']} (final);"
          f" {a['added']} added, {a['retired']} drained + retired")
    print("scale events:")
    for ev in a["events"]:
        print(f"  t={ev['t']:5.2f}s  {ev['action']:4s} -> {ev['live']} live "
              f"(target {ev['want']})")
    print("\nSame trace, same seed, same containers — the only difference is "
          "the control loop\nwatching queue depth, arrival rate, and service "
          "times each 50 ms tick (InferLine-style).")


if __name__ == "__main__":
    main()
