"""Cascade prediction-pipeline demo (repro.pipeline, DESIGN.md §12).

Serves one seeded Zipf-skewed trace two ways and prints the story side by
side:

* **monolithic** — every query goes to the accurate (expensive) model;
* **cascade**    — a preprocess stage feeds a cheap two-model draft
  ensemble; only queries where the drafts *disagree*
  (``agreement_confidence`` below the threshold) escalate to the accurate
  model, and the intermediate-result cache answers repeated prefixes
  outright.

Same trace, same SLO, same accurate model — the cascade wins tail latency
and replica-seconds because the expensive model only sees the queries that
actually need it.

Run:  PYTHONPATH=src python examples/cascade_pipeline.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.frontend import make_clipper
from repro.pipeline import pipeline_models, pipeline_scenario, run_pipeline
from repro.workloads import query_trace
from repro.workloads.scenario import D_FEAT


def describe(tag, rep):
    q = rep["queries"]
    cost = sum(pm["service_s"]["sum"] or 0.0
               for pm in rep["per_model"].values())
    print(f"{tag:10s}: attainment={rep['slo']['attainment']:.3f}  "
          f"p50={rep['latency_s']['p50']*1e3:6.2f} ms  "
          f"p99={rep['latency_s']['p99']*1e3:6.2f} ms  "
          f"cost={cost:.3f} replica-s  "
          f"({q['completed']}/{q['submitted']} served)")
    return cost


def main():
    sc = pipeline_scenario()
    print(f"pipeline regime: {sc.rate:.0f} qps, SLO {sc.slo*1e3:.0f} ms, "
          f"Zipf pool of {sc.pool} unique queries\n")

    models, lat, _, _ = pipeline_models(sc)
    mono = make_clipper({"accurate": models["accurate"]}, "exp4",
                        slo=sc.slo, latency_models={"accurate": lat["accurate"]},
                        seed=sc.seed)
    mono.replay(query_trace(sc.arrival_times(), sc.seed, d_feat=D_FEAT,
                            pool=sc.pool))
    mono_cost = describe("monolithic", mono.report())

    rep = run_pipeline(sc, "cascade")
    casc_cost = describe("cascade", rep)

    p = rep["pipeline"]
    print(f"\ncascade internals: {p['stage_jobs']} stage jobs for "
          f"{rep['queries']['submitted']} queries; "
          f"{p['escalations']} escalated to the accurate model "
          f"({p['escalation_rate']*100:.1f}%), {p['stages_skipped']} "
          f"answered by the draft tier alone")
    print("intermediate cache hit rate per stage model:")
    for mid, pm in sorted(rep["per_model"].items()):
        c = pm["cache"]
        print(f"  {mid:9s} {c['hit_rate']:.3f}  "
              f"({c['hits']} hits / {c['misses']} misses)")
    split = p["slo_split"]["shares"]
    print("per-stage SLO split (ms): "
          + "  ".join(f"{k}={v*1e3:.2f}" for k, v in split.items()))
    print(f"\ncost: {mono_cost:.3f} -> {casc_cost:.3f} replica-seconds "
          f"({(1 - casc_cost/mono_cost)*100:.0f}% cheaper), tail served by "
          "the model that earns it.")


if __name__ == "__main__":
    main()
