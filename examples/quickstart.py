"""Quickstart: serve an LM with Clipper-style adaptive batching.

End-to-end driver (the paper's kind is serving): build a small transformer
from the assigned-architecture family, stand up the continuous-batching
LMServer with AIMD admission control, and serve a stream of batched
requests.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import numpy as np

from repro.configs.registry import ARCHITECTURES, reduced_config
from repro.distributed.sharding import serve_rules
from repro.launch.mesh import make_local_mesh
from repro.models.api import build_model
from repro.serving.engine import LMServer


def main():
    mesh = make_local_mesh()
    rules = serve_rules(multi_pod=False)

    # --arch smollm-360m, reduced for CPU; the same build_model call with the
    # full config is what the dry-run lowers for the 16x16 TPU mesh.
    cfg = reduced_config(ARCHITECTURES["smollm-360m"], num_layers=4,
                         d_model=128)
    model = build_model(cfg, mesh, rules)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model}, "
          f"{ARCHITECTURES['smollm-360m'].param_count()/1e6:.0f}M at full size)")

    server = LMServer(model, mesh, rules, slots=8, max_len=128,
                      temperature=0.8, seed=0)
    rng = np.random.default_rng(0)

    t0 = time.perf_counter()
    rids = []
    for i in range(24):
        prompt = rng.integers(0, cfg.vocab_size, size=16)
        rids.append(server.submit(prompt, max_new_tokens=24))
    server.run(params)
    dt = time.perf_counter() - t0

    total_tokens = sum(len(server.completed[r].tokens) for r in rids)
    print(f"completed {len(server.completed)} requests, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.0f} tok/s on 1 CPU core)")
    print(f"AIMD admission batch size converged to: "
          f"{server.admission.max_batch_size}")
    r = server.completed[rids[0]]
    print(f"sample generation (request 0): {r.tokens[:12]}...")

    # shared telemetry schema (core/metrics.py) — same fields the Clipper
    # frontend and `python -m repro.workloads.run` report
    rep = server.report()
    lat, bs = rep["latency_s"], rep["batch_size"]
    print(f"telemetry: p50={lat['p50']*1e3:.0f}ms p99={lat['p99']*1e3:.0f}ms "
          f"throughput={rep['throughput_qps']:.1f} req/s "
          f"slo_violation_rate={rep['slo']['rate']:.2f} "
          f"mean_batch={bs['mean']:.1f}")


if __name__ == "__main__":
    main()
