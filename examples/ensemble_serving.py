"""Ensemble serving with online model selection (paper §5 end to end).

Deploys five models of graded quality behind the Clipper frontend with the
Exp4 ensemble policy, streams queries with feedback, injects a model failure
mid-stream, and shows the selection layer routing around it (Fig 8 live).

Run:  PYTHONPATH=src python examples/ensemble_serving.py
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import make_task, np_call, train_linear_model
from repro.core import Feedback, linear_latency, make_clipper
from repro.core.selection import exp4_weights


def main():
    rng = np.random.default_rng(0)
    W, label = make_task(rng)
    print("training 5 base models (graded label noise)...")
    models, state = {}, {"broken": False}
    for i, nz in enumerate([0.5, 0.4, 0.3, 0.2, 0.1]):
        fn = np_call(train_linear_model(rng, W, noise=nz))
        if i == 4:                                    # best model, will fail
            base = fn
            fn = (lambda x: rng.normal(size=(len(x), W.shape[1]))
                  if state["broken"] else base(x))
        models[f"m{i}"] = fn

    clip = make_clipper(
        models, "exp4", slo=0.020,
        latency_models={m: linear_latency(0.001, 2e-5) for m in models})

    t, window_err = 0.0, []

    def serve(n, tag):
        nonlocal t
        errs = []
        for _ in range(n):
            x = rng.normal(size=(W.shape[0],)).astype(np.float32)
            clip.run(until=t)
            qid = clip.submit(x, arrival_time=t)
            t += 0.002
            clip.run()
            pred = clip.results[qid]
            y = int(label(x[None])[0])
            errs.append(int(np.argmax(pred.y) != y))
            clip.feedback(Feedback(qid, x, y))
        w = np.asarray(exp4_weights(clip.policy_state))
        print(f"  [{tag}] err={np.mean(errs):.3f}  "
              f"weights={np.array2string(w, precision=2)}")
        return np.mean(errs)

    print("phase 1: all models healthy")
    serve(400, "healthy")
    print("phase 2: best model (m4) fails — watch Exp4 reroute")
    state["broken"] = True
    serve(400, "failed ")
    print("phase 3: m4 recovers")
    state["broken"] = False
    serve(400, "healed ")
    print("done — the ensemble absorbed a model failure with no operator "
          "action (paper Fig 8).")

    rep = clip.report()
    print(f"telemetry: served={rep['queries']['completed']} "
          f"p99={rep['latency_s']['p99']*1e3:.1f}ms "
          f"slo_violations={rep['slo']['violations']} "
          f"cache_hit_rate={rep['cache']['hit_rate']:.2f} "
          f"stragglers={rep['stragglers']['partial_queries']}")


if __name__ == "__main__":
    main()
