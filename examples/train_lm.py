"""Train a ~100M-parameter LM for a few hundred steps with the full
production substrate: deterministic sharded data pipeline, AdamW with
cosine schedule, microbatch gradient accumulation, NaN-step skipping, and
checkpoint/restart (kill it mid-run and re-launch — it resumes).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]

By default a width-reduced smollm variant (~8M params) runs quickly on this
CPU container; --full trains the true smollm-360m config (slow on CPU, the
config the 16x16 dry-run lowers).
"""

import argparse
import dataclasses

import jax

from repro.configs.base import ShapeSpec
from repro.configs.registry import ARCHITECTURES, get_config, reduced_config
from repro.data.pipeline import data_iter
from repro.distributed.sharding import train_rules
from repro.launch.mesh import make_local_mesh
from repro.models.api import build_model
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg, num_layers=6, d_model=256, vocab_size=4096)
        cfg = dataclasses.replace(cfg, d_ff=0 if cfg.d_ff == 0 else 1024)
    shape = ShapeSpec("train_small", 256, 16, "train")
    mesh = make_local_mesh()
    rules = train_rules(multi_pod=False)
    model = build_model(cfg, mesh, rules)
    n_params = cfg.param_count()
    print(f"training {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {shape.global_batch}x{shape.seq_len}")

    tc = TrainConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                     num_microbatches=4)
    with mesh:
        out = train(model, mesh, rules, tc,
                    data_iter(cfg, shape), num_steps=args.steps,
                    checkpoint_dir=args.ckpt, checkpoint_every=50,
                    log_every=20,
                    hooks={"on_log": lambda m: print(
                        f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
                        f"gnorm {m['gnorm']:.2f}  lr {m['lr']:.2e}")})
    h = out["history"]
    print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"(checkpoints in {args.ckpt}; rerun to resume)")


if __name__ == "__main__":
    main()
