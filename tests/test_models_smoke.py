"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step on CPU, assert output shapes + no NaNs (assignment
requirement), plus prefill/decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeSpec, applicable_shapes
from repro.configs.registry import ARCHITECTURES, reduced_config
from repro.distributed.sharding import train_rules
from repro.launch.inputs import (make_concrete, prefill_batch_specs,
                                 train_batch_specs)
from repro.models.api import build_model
from repro.launch.mesh import compat_make_mesh

SHAPE = ShapeSpec("smoke", 32, 2, "train")
ALL_ARCHS = sorted(ARCHITECTURES)


@pytest.fixture(scope="module")
def mesh():
    return compat_make_mesh((1, 1), ("data", "model"))


def _build(name, mesh):
    cfg = reduced_config(ARCHITECTURES[name])
    rules = train_rules(False)
    model = build_model(cfg, mesh, rules)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_shapes_and_finite(name, mesh):
    cfg, model, params = _build(name, mesh)
    batch = make_concrete(train_batch_specs(cfg, SHAPE), vocab=cfg.vocab_size)
    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{name} loss not finite"
    gnorm = sum(jnp.sum(jnp.abs(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm), f"{name} grads not finite"


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_finite_and_shaped(name, mesh):
    cfg, model, params = _build(name, mesh)
    pb = make_concrete(prefill_batch_specs(cfg, SHAPE), vocab=cfg.vocab_size)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=64)
                            )(params, pb)
    V = cfg.padded(1).vocab_size
    assert logits.shape == (2, V)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    toks = jnp.zeros((2, 1), jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, toks,
                                                 cache["lengths"])
    assert logits2.shape == (2, V)
    assert jnp.isfinite(logits2.astype(jnp.float32)).all()
    assert int(cache2["lengths"][0]) == int(cache["lengths"][0]) + 1


@pytest.mark.parametrize("name", ["granite-8b", "qwen2-7b", "hymba-1.5b",
                                  "xlstm-125m", "seamless-m4t-medium",
                                  "internvl2-1b"])
def test_decode_matches_prefill(name, mesh):
    """Teacher-forced decode of token S must match prefill of S+1 tokens."""
    cfg, model, params = _build(name, mesh)
    rng = np.random.default_rng(0)
    S = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S + 1)), jnp.int32)
    full = {"tokens": toks}
    pre = {"tokens": toks[:, :S]}
    if cfg.frontend == "vision":
        emb = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)) * 0.02,
                          jnp.float32)
        full["prefix_embeddings"] = emb
        pre["prefix_embeddings"] = emb
    if cfg.is_encoder_decoder:
        fr = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)) * 0.02,
                         jnp.float32)
        full["frames"] = fr
        pre["frames"] = fr
    # cache capacity must cover prefix embeddings + text + 1 appended token
    cap = S + 1 + (8 if cfg.frontend == "vision" else 0)
    lg_full, _ = model.prefill(params, full, max_len=cap)
    _, cache = model.prefill(params, pre, max_len=cap)
    lg_dec, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                                  cache["lengths"])
    err = float(jnp.max(jnp.abs(lg_full.astype(jnp.float32)
                                - lg_dec.astype(jnp.float32))))
    assert err < 0.1, f"{name}: prefill/decode divergence {err}"


def test_long_500k_applicability_rule():
    names = {c.name for c, s in
             ((ARCHITECTURES[n], None) for n in ARCHITECTURES)
             if not ARCHITECTURES[c.name].is_full_attention}
    long_archs = {c.name for n, c in ARCHITECTURES.items()
                  if any(s.name == "long_500k" for s in applicable_shapes(c))}
    assert long_archs == {"xlstm-125m", "hymba-1.5b"}


def test_param_counts_match_published_scale():
    """Logical parameter counts are in the right ballpark for the names."""
    expect = {
        "dbrx-132b": (110e9, 150e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "granite-8b": (6e9, 10e9),
        "qwen2-7b": (6e9, 9e9),
        "smollm-360m": (0.3e9, 0.5e9),
        "minitron-8b": (7e9, 10e9),
        "internvl2-1b": (0.4e9, 1.2e9),
        "xlstm-125m": (0.1e9, 0.2e9),
        "hymba-1.5b": (1.2e9, 2.2e9),
        "seamless-m4t-medium": (0.5e9, 1.5e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHITECTURES[name].param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"


def test_moe_active_params():
    kimi = ARCHITECTURES["kimi-k2-1t-a32b"]
    active = kimi.active_param_count()
    assert 25e9 <= active <= 45e9           # "a32b"
