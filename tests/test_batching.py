"""Adaptive batching: AIMD + quantile regression + queues (paper §4.3)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.batching import (AIMDController, BatchQueue, FixedController,
                                 QuantileRegressionController, bucket)
from repro.core.interfaces import Query


def _run_to_convergence(ctrl, latency_fn, iters=400):
    for _ in range(iters):
        b = ctrl.max_batch_size
        ctrl.record(b, latency_fn(b))
    return ctrl.max_batch_size


def test_aimd_converges_to_slo_boundary():
    """latency = 1ms + 0.5ms*b, SLO 20ms -> optimum b = 38; AIMD oscillates
    in a one-backoff band around it."""
    ctrl = AIMDController(0.020, additive=2, backoff=0.9)
    lat = lambda n: 0.001 + 0.0005 * n
    b = _run_to_convergence(ctrl, lat)
    assert 34 <= b <= 40
    assert lat(int(b * 0.9)) <= 0.020       # one backoff puts it under SLO


def test_aimd_adapts_downward():
    """After convergence, a slowdown (paper: GC pause / replica change)
    drives the max batch size back down."""
    ctrl = AIMDController(0.020)
    _run_to_convergence(ctrl, lambda n: 0.001 + 0.0005 * n)
    b_fast = ctrl.max_batch_size
    b_slow = _run_to_convergence(ctrl, lambda n: 0.001 + 0.002 * n)
    assert b_slow < b_fast
    assert 0.001 + 0.002 * b_slow <= 0.020 * 1.15


def test_quantile_regression_close_to_aimd():
    """Fig 4: the two strategies find similar operating points."""
    lat = lambda n: 0.001 + 0.0005 * n
    a = _run_to_convergence(AIMDController(0.020), lat)
    q = QuantileRegressionController(0.020)
    rng = np.random.default_rng(0)
    for _ in range(600):
        b = max(1, int(rng.integers(1, max(2, q.max_batch_size + 4))))
        q.record(b, lat(b) * (1 + abs(rng.normal(0, 0.02))))
    assert abs(q.max_batch_size - a) <= max(8, int(0.35 * a))


@given(st.integers(1, 5000))
def test_bucket_pow2(n):
    b = bucket(n)
    assert b >= n
    assert b < 2 * n or b == 1
    assert b & (b - 1) == 0 or n > 4096


@given(st.floats(0.002, 0.1), st.floats(1e-5, 1e-3), st.floats(1e-6, 1e-4))
@settings(max_examples=40, deadline=None)
def test_aimd_never_exceeds_slo_steady_state(slo, base, per_item):
    """Property: once converged, latency at the chosen batch size is within
    one backoff step of the SLO for any linear latency profile."""
    ctrl = AIMDController(slo, additive=1, backoff=0.9)
    lat = lambda n: base + per_item * n
    b = _run_to_convergence(ctrl, lat, iters=800)
    if lat(1) > slo:            # SLO unattainable: pinned at 1
        assert b == 1
    else:
        assert lat(max(1, int(b * 0.9) - 1)) <= slo * 1.05


def test_batch_queue_delay_and_admission():
    ctrl = FixedController(4)
    q = BatchQueue(ctrl, batch_delay=0.002)
    q.put(Query(0, None, arrival_time=0.0))
    assert not q.ready(0.001)            # delaying for more arrivals
    assert q.ready(0.0025)               # delay elapsed
    for i in range(1, 5):
        q.put(Query(i, None, arrival_time=0.001))
    assert q.ready(0.001)                # full batch short-circuits the delay
    batch = q.next_batch(0.001)
    assert len(batch) == 4 and len(q) == 1
