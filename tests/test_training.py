"""Training substrate: optimizer, grad accumulation, compression, loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ShapeSpec
from repro.configs.registry import ARCHITECTURES, reduced_config
from repro.data.pipeline import data_iter
from repro.distributed.sharding import train_rules
from repro.models.api import build_model
from repro.training import optimizer as opt_lib
from repro.training.grad_compress import _accumulate, _quantized_pod_mean
from repro.training.train_loop import TrainConfig, train
from repro.launch.mesh import compat_make_mesh


@pytest.fixture(scope="module")
def mesh():
    return compat_make_mesh((1, 1), ("data", "model"))


def test_adamw_reduces_quadratic():
    w = {"w": jnp.asarray([5.0, -3.0])}
    state = opt_lib.adamw_init(w)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(w)
        w, state, _ = opt_lib.adamw_update(g, state, w, lr=0.05,
                                           weight_decay=0.0)
    assert float(loss(w)) < 1e-2


def test_adafactor_reduces_quadratic():
    w = {"w": jnp.ones((4, 4)) * 3.0}
    state = opt_lib.adafactor_init(w)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(w)
        w, state, _ = opt_lib.adafactor_update(g, state, w, lr=0.05)
    assert float(loss(w)) < 1e-1


def test_grad_clip_bounds_update():
    w = {"w": jnp.asarray([0.0])}
    state = opt_lib.adamw_init(w)
    huge = {"w": jnp.asarray([1e9])}
    w2, _, gnorm = opt_lib.adamw_update(huge, state, w, lr=0.1,
                                        weight_decay=0.0, grad_clip=1.0)
    assert float(gnorm) == pytest.approx(1e9)
    assert abs(float(w2["w"][0])) < 1.0


def test_cosine_schedule_shape():
    sched = opt_lib.cosine_schedule(1e-3, warmup=10, total=100)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(sched(100)) < 1e-5
    assert float(sched(55)) < float(sched(20))


def test_microbatch_accumulation_matches_full_batch():
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(4, 2)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)
    loss_fn = lambda p, b: jnp.mean((b["x"] @ p - b["y"]) ** 2)
    l1, g1 = _accumulate(loss_fn, W, {"x": x, "y": y}, 1)
    l4, g4 = _accumulate(loss_fn, W, {"x": x, "y": y}, 4)
    assert float(l1) == pytest.approx(float(l4), rel=1e-5)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g4), rtol=1e-5)


@given(st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_int8_quantization_error_bound(seed):
    """|dequant(quant(g)) - mean(g)| <= scale = max|g|/127 per element."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(2, 16))
                    * 10.0 ** float(rng.integers(-3, 3)), jnp.float32)
    out = _quantized_pod_mean(g)
    ref = jnp.mean(g, axis=0)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(out - ref))) <= scale + 1e-7


def test_training_loss_decreases(mesh):
    cfg = reduced_config(ARCHITECTURES["smollm-360m"])
    shape = ShapeSpec("tiny", 32, 8, "train")
    rules = train_rules(False)
    model = build_model(cfg, mesh, rules)
    tc = TrainConfig(lr=1e-2, warmup_steps=2, total_steps=40,
                     num_microbatches=2)
    with mesh:
        out = train(model, mesh, rules, tc, data_iter(cfg, shape),
                    num_steps=25, log_every=5)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] * 0.8


def test_nan_step_skipped(mesh):
    """A batch that produces NaN loss must not corrupt parameters."""
    cfg = reduced_config(ARCHITECTURES["smollm-360m"], num_layers=2)
    shape = ShapeSpec("tiny", 16, 4, "train")
    rules = train_rules(False)
    model = build_model(cfg, mesh, rules)
    from repro.training.train_loop import jit_train_step
    from repro.launch.inputs import train_batch_specs, make_concrete
    tc = TrainConfig(num_microbatches=1, skip_nan_steps=True)
    specs = train_batch_specs(cfg, shape)
    with mesh:
        step, opt_init, sh, _ = jit_train_step(model, mesh, rules, tc, specs)
        params = model.init(jax.random.PRNGKey(0))
        opt = opt_init(params)
        bad_loss_fn = model.loss_fn

        # poison loss by feeding out-of-range labels? instead: scale params to inf
        poisoned = jax.tree.map(lambda p: p * jnp.inf, params)
        batch = make_concrete(specs, vocab=cfg.vocab_size)
        p2, o2, m = step(poisoned, opt_init(poisoned), batch)
        # step reported non-finite and params unchanged (still inf, not NaN-mixed)
        assert not np.isfinite(m["loss"])
