"""Contextual (per-user) selection store (paper §5.3)."""

import numpy as np
import pytest

from repro.core.context import ContextualStore


def test_per_user_isolation_exp4():
    store = ContextualStore(num_users=4, k=2, kind="exp4", eta=0.3)
    # user 0 sees model 0 failing; user 1 sees model 1 failing
    for _ in range(50):
        store.observe_exp4(np.array([0]), np.array([[0.9, 0.0]]))
        store.observe_exp4(np.array([1]), np.array([[0.0, 0.9]]))
    import jax.nn as jnn
    w0 = np.asarray(jnn.softmax(store.state_for(0)))
    w1 = np.asarray(jnn.softmax(store.state_for(1)))
    assert w0[1] > 0.9 and w1[0] > 0.9
    w2 = np.asarray(jnn.softmax(store.state_for(2)))   # untouched user: uniform
    np.testing.assert_allclose(w2, [0.5, 0.5], atol=1e-6)


def test_batched_update_matches_sequential():
    a = ContextualStore(num_users=8, k=3, kind="exp4", eta=0.1)
    b = ContextualStore(num_users=8, k=3, kind="exp4", eta=0.1)
    losses = np.array([[0.1, 0.5, 0.9], [0.9, 0.5, 0.1], [0.4, 0.4, 0.4]])
    users = np.array([2, 5, 7])
    a.observe_exp4(users, losses)
    for u, l in zip(users, losses):
        b.observe_exp4(np.array([u]), l[None])
    np.testing.assert_allclose(np.asarray(a.states), np.asarray(b.states),
                               atol=1e-6)


def test_exp3_contextual_update():
    store = ContextualStore(num_users=2, k=2, kind="exp3", eta=0.5)
    for _ in range(30):
        store.observe_exp3(np.array([0]), np.array([0]), np.array([1.0]))
    p = store.probs_for(0)
    assert p[0] < 0.3                      # model 0 repeatedly penalized


def test_state_dict_roundtrip():
    store = ContextualStore(num_users=4, k=2)
    store.observe_exp4(np.array([1]), np.array([[0.9, 0.0]]))
    d = store.state_dict()
    store2 = ContextualStore(num_users=4, k=2)
    store2.load_state_dict(d)
    np.testing.assert_allclose(np.asarray(store.states),
                               np.asarray(store2.states))
