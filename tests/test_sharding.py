"""Sharding rules, spec construction, batch-divisibility fitting."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import ARCHITECTURES
from repro.distributed.sharding import (ShardingContext, serve_rules,
                                        strip_pod, train_rules)
from repro.launch.steps import fit_batch_sharding
from repro.launch.mesh import compat_make_mesh


@pytest.fixture(scope="module")
def mesh22():
    return compat_make_mesh((1, 1), ("data", "model"))


def test_spec_dedupes_repeated_mesh_axes(mesh22):
    rules = {"expert": "model", "fsdp": "data", "expert_ffn": "data"}
    ctx = ShardingContext(mesh22, rules)
    # fsdp and expert_ffn both map to data: second occurrence dropped
    spec = ctx.spec(("expert", "fsdp", "expert_ffn"))
    assert spec == P("model", "data")


def test_spec_trailing_nones_trimmed(mesh22):
    ctx = ShardingContext(mesh22, train_rules(False))
    assert ctx.spec(("batch", None, None)) == P(("data",))


def test_strip_pod():
    r = train_rules(True)
    assert r["batch"] == ("pod", "data")
    s = strip_pod(r)
    assert s["batch"] == ("data",)
    assert s["users"] == ("data",)


def test_serve_rules_replicate_fsdp():
    assert serve_rules(False)["fsdp"] is None
    assert train_rules(False)["fsdp"] == "data"
    assert serve_rules(False, shard_experts_2d=True)["expert_ffn"] == "data"


def test_fit_batch_sharding_drops_axes():
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    rules = dict(train_rules(False))
    # batch of 1 cannot shard over data=1? it can (1 % 1 == 0)
    out = fit_batch_sharding(rules, mesh, 1)
    assert out["batch"] == ("data",)


def test_padding_rules_all_archs():
    """Every arch's padded dims divide cleanly by tp=16 (the dry-run mesh)."""
    for name, cfg in ARCHITECTURES.items():
        pd = cfg.padded(16)
        assert pd.num_q_heads % 16 == 0 or pd.num_q_heads % pd.num_kv_heads == 0
        assert pd.num_q_heads % pd.num_kv_heads == 0, name
        assert pd.vocab_size % 16 == 0, name
        assert pd.num_kv_heads % 16 == 0 or 16 % pd.num_kv_heads == 0, name
        assert pd.num_q_heads >= cfg.num_heads
        assert pd.vocab_size >= cfg.vocab_size


def test_padded_tp1_is_logical():
    for cfg in ARCHITECTURES.values():
        pd = cfg.padded(1)
        assert pd.num_q_heads == cfg.num_heads
        assert pd.num_kv_heads == cfg.num_kv_heads


def test_cell_accounting():
    """40 nominal cells; 8 long_500k skipped for full-attention archs."""
    from repro.configs.registry import all_cells
    cells = list(all_cells())
    assert len(cells) == 32
    long_archs = {c.name for c, s in cells if s.name == "long_500k"}
    assert long_archs == {"xlstm-125m", "hymba-1.5b"}
