"""Straggler mitigation math (paper §5.2.2)."""

import numpy as np
import pytest

from repro.core.straggler import (DeadlineTracker, agreement_confidence,
                                  assemble_preds)


def test_assemble_mean_substitution():
    preds = {"a": np.array([1.0, 0.0]), "c": np.array([0.0, 1.0])}
    mat, avail = assemble_preds(["a", "b", "c"], preds)
    assert list(avail) == [True, False, True]
    np.testing.assert_allclose(np.asarray(mat[1]), [0.5, 0.5])


def test_assemble_all_missing_raises():
    with pytest.raises(ValueError):
        assemble_preds(["a"], {})


def test_agreement_confidence():
    import jax.numpy as jnp
    mat = jnp.asarray([[0.9, 0.1], [0.8, 0.2], [0.1, 0.9]])
    avail = jnp.asarray([True, True, True])
    assert abs(agreement_confidence(mat, avail) - 2 / 3) < 1e-6
    avail2 = jnp.asarray([True, True, False])
    assert agreement_confidence(mat, avail2) == 1.0


def test_deadline_tracker():
    d = DeadlineTracker(0.02)
    assert d.deadline_for(1.0) == 1.02
    assert not d.expired(1.0, 1.01)
    assert d.expired(1.0, 1.03)
    assert abs(d.remaining(1.0, 1.005) - 0.015) < 1e-9
