"""End-to-end system behaviour: the paper's headline claims reproduced in
miniature (details in benchmarks/, these are the fast regression versions)."""

import jax
import numpy as np
import pytest

from repro.core import (Feedback, linear_latency, make_clipper)
from repro.core.selection import exp4_weights


def _make_task(rng, k_classes=3, d=6):
    W = rng.normal(size=(d, k_classes))

    def label(x):
        return int(np.argmax(x @ W))

    return W, label


def _trained_models(rng, W, noise_levels):
    """Linear models of varying quality on the synthetic task."""
    models = {}
    for i, nz in enumerate(noise_levels):
        Wn = W + rng.normal(size=W.shape) * nz

        def fn(x, Wn=Wn):
            z = x @ Wn
            e = np.exp(z - z.max(axis=1, keepdims=True))
            return e / e.sum(axis=1, keepdims=True)

        models[f"m{i}"] = fn
    return models


def test_adaptive_batching_increases_throughput():
    """Paper §4.3 headline: batching provides large throughput gains under a
    latency SLO vs no batching."""
    rng = np.random.default_rng(0)
    lat = linear_latency(0.004, 0.00005)     # high fixed cost, cheap per item
    def fn(x):
        return np.zeros((len(x), 3))

    def run(aimd_kwargs, n=400, gap=0.0002):
        clip = make_clipper({"m": fn}, "exp4", slo=0.02,
                            latency_models={"m": lat},
                            aimd_kwargs=aimd_kwargs)
        trace = [(i * gap, rng.normal(size=(4,)).astype(np.float32), 0)
                 for i in range(n)]
        qids = clip.replay(trace)
        done = clip.now - trace[0][0]
        return n / done

    thr_batched = run({})
    thr_unbatched = run({"max_batch": 1})
    assert thr_batched > 3 * thr_unbatched


def test_ensemble_beats_single_model_accuracy():
    """Paper §5.2: the ensemble reduces error vs individual models."""
    rng = np.random.default_rng(1)
    W, label = _make_task(rng)
    models = _trained_models(rng, W, [0.6, 0.7, 0.8, 0.9, 1.0])
    xs = rng.normal(size=(400, 6)).astype(np.float32)
    singles = []
    for mid, fn in models.items():
        singles.append(np.mean([np.argmax(fn(x[None])[0]) == label(x)
                                for x in xs]))
    ens = np.mean([np.argmax(np.mean([fn(x[None])[0]
                                      for fn in models.values()], axis=0))
                   == label(x) for x in xs])
    assert ens >= max(singles) - 0.02        # at least on par with the best


def test_model_failure_recovery_end_to_end():
    """Paper Fig 8 in miniature: Exp4 routes around a degraded model."""
    rng = np.random.default_rng(2)
    W, label = _make_task(rng)
    models = _trained_models(rng, W, [0.1, 0.8])
    state = {"broken": False}
    base = models["m0"]

    def flaky(x):
        if state["broken"]:
            return rng.normal(size=(len(x), 3))
        return base(x)

    models["m0"] = flaky
    clip = make_clipper(models, "exp4", slo=0.05,
                        latency_models={m: linear_latency(0.0005, 1e-5)
                                        for m in models})
    t = 0.0

    def interact(n):
        nonlocal t
        errs = []
        for _ in range(n):
            x = rng.normal(size=(6,)).astype(np.float32)
            clip.run(until=t)
            qid = clip.submit(x, arrival_time=t)
            t += 0.002
            clip.run()
            y = clip.results[qid].y
            errs.append(int(np.argmax(y) != label(x)))
            clip.feedback(Feedback(qid, x, label(x)))
        return np.mean(errs)

    e_before = interact(150)
    w_before = np.asarray(exp4_weights(clip.policy_state))
    state["broken"] = True
    interact(200)                             # adaptation window
    w_after = np.asarray(exp4_weights(clip.policy_state))
    e_after = interact(100)
    # weight on m0 collapsed after failure
    assert w_after[0] < w_before[0] * 0.5
    # error rate recovered to near the healthy backup's level
    assert e_after < 0.65


def test_confidence_thresholding_reduces_error():
    """Paper §5.2.1: accepting only high-agreement predictions cuts error."""
    rng = np.random.default_rng(3)
    W, label = _make_task(rng)
    models = _trained_models(rng, W, [0.5, 0.6, 0.7, 0.8, 0.9])
    clip = make_clipper(models, "exp4", slo=0.05,
                        latency_models={m: linear_latency(0.0005, 1e-5)
                                        for m in models})
    xs = [rng.normal(size=(6,)).astype(np.float32) for _ in range(300)]
    qids = clip.replay([(i * 0.002, x, 0) for i, x in enumerate(xs)])
    rows = [(clip.results[q].confidence,
             int(np.argmax(clip.results[q].y) != label(x)))
            for q, x in zip(qids, xs)]
    all_err = np.mean([e for _, e in rows])
    confident = [e for c, e in rows if c >= 0.99]
    assert len(confident) > 10
    assert np.mean(confident) < all_err
