"""Device-resident serving hot path (DESIGN.md §11): fused decode step vs
the reference per-slot loop, batched cache scatter vs per-request scatter,
prompt-length ladder exactness, and Pallas-vs-jnp decode attention parity
through the backend switch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHITECTURES, reduced_config
from repro.core.batching import bucket, prompt_length_ladder
from repro.core.metrics import VirtualClock
from repro.distributed.sharding import serve_rules
from repro.launch.mesh import compat_make_mesh
from repro.models.api import build_model
from repro.models.common import (attention_decode, attention_decode_auto,
                                 get_attention_backend,
                                 set_attention_backend)
from repro.serving.engine import (LMServer, _scatter_cache, batched_scatter,
                                  make_fused_decode_fn)
from repro.serving.sampler import sample

FAMILIES = {
    "dense": "smollm-360m",
    "ssm": "xlstm-125m",
    "hybrid": "hymba-1.5b",
    "encdec": "seamless-m4t-medium",
}


@pytest.fixture(scope="module")
def mesh():
    return compat_make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def built(mesh):
    out = {}
    for fam, name in FAMILIES.items():
        cfg = reduced_config(ARCHITECTURES[name])
        model = build_model(cfg, mesh, serve_rules(False))
        params = model.init(jax.random.PRNGKey(0))
        out[fam] = (cfg, model, params)
    return out


def _sim_server(model, mesh, seed=0, **kw):
    clock = VirtualClock()

    def service_model(kind, batch, tokens):
        return 0.004 + 5e-5 * batch * tokens if kind == "prefill" \
            else 0.001 + 5e-5 * batch

    return LMServer(model, mesh, serve_rules(False), max_len=64,
                    clock=clock, service_model=service_model, seed=seed,
                    **kw)


# ---------------------------------------------------------------------------
# fused vs reference: byte-identical token streams
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_fused_matches_reference_byte_identical(built, mesh, temperature):
    """Acceptance: the fused decode step produces byte-identical token
    streams to the reference engine for a fixed seed in calibrated-sim
    mode (same-length prompts, so admission batching is identical)."""
    cfg, model, params = built["dense"]
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(7)]
    streams = {}
    for fused in (True, False):
        srv = _sim_server(model, mesh, slots=4, fused=fused,
                          temperature=temperature)
        rids = [srv.submit(p, max_new_tokens=5) for p in prompts]
        srv.run(params)
        streams[fused] = [srv.completed[r].tokens for r in rids]
    assert streams[True] == streams[False]


@pytest.mark.parametrize("fam", ["dense", "ssm", "hybrid"])
def test_fused_mixed_lengths_matches_reference_greedy(built, mesh, fam):
    """Mixed-length traces: the ladder pads prompts while the reference
    engine same-length-groups them — batching differs, but greedy decode is
    per-sample deterministic and padded prefill is exact, so per-request
    token streams must agree across the two engines."""
    cfg, model, params = built[fam]
    rng = np.random.default_rng(1)
    lens = [4, 9, 4, 13, 6]
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in lens]
    streams = {}
    for fused in (True, False):
        srv = _sim_server(model, mesh, slots=4, fused=fused)
        rids = [srv.submit(p, max_new_tokens=4) for p in prompts]
        srv.run(params)
        streams[fused] = [srv.completed[r].tokens for r in rids]
    assert streams[True] == streams[False]
    if fam != "ssm":        # attention families pad under the ladder
        srv = _sim_server(model, mesh, slots=4, fused=True)
        assert srv.pad_prompts


def test_fused_host_syncs_O1_reference_O_slots(built, mesh):
    """The hot-path contract: one host transfer per fused decode step; the
    reference loop pays 1 + one per active slot."""
    cfg, model, params = built["dense"]
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(6)]
    stats = {}
    for fused in (True, False):
        srv = _sim_server(model, mesh, slots=4, fused=fused)
        for p in prompts:
            srv.submit(p, max_new_tokens=6)
        srv.run(params)
        stats[fused] = srv.stats
    assert stats[True]["host_syncs_per_decode_step"] == 1.0
    assert stats[False]["host_syncs_per_decode_step"] > 1.5


# ---------------------------------------------------------------------------
# fused step builder: token-for-token parity across all four families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_fused_step_token_parity_all_families(built, mesh, fam):
    """Drive make_fused_decode_fn directly against a reference loop that
    reproduces the per-slot Python bookkeeping, from the same prefilled
    cache — tokens and done transitions must match step for step."""
    cfg, model, params = built[fam]
    rules = serve_rules(False)
    rng = np.random.default_rng(3)
    slots, max_len, plen, max_new = 3, 32, 6, 5
    toks = rng.integers(0, cfg.vocab_size, (2, plen)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(2, 8, cfg.d_model)) * 0.02, jnp.float32)
    logits, pcache = model.prefill(params, batch, max_len=max_len)
    first = np.asarray(sample(logits, jax.random.PRNGKey(9)))

    def scattered():
        cache = model.init_cache(slots, max_len)
        mask = jnp.asarray([True, True, False])
        src = jnp.asarray([0, 1, 0], jnp.int32)
        return batched_scatter(cache, pcache, mask, src)

    lengths0 = jnp.asarray([plen, plen, 0], jnp.int32)
    cur0 = jnp.asarray([[first[0]], [first[1]], [0]], jnp.int32)

    # fused path
    fused = jax.jit(make_fused_decode_fn(
        model, mesh, rules, temperature=0.0, eos=-1, max_len=max_len))
    cache = scattered()
    lengths, cur = lengths0, cur0
    active = jnp.asarray([True, True, False])
    gen = jnp.asarray([1, 1, 0], jnp.int32)
    maxn = jnp.asarray([max_new, max_new, 0], jnp.int32)
    key = jax.random.PRNGKey(4)
    fused_toks, fused_done = [], []
    for _ in range(max_new):
        key, k = jax.random.split(key)
        packed, cache, lengths, cur, active, gen = fused(
            params, cache, lengths, cur, active, gen, maxn, k)
        out = np.asarray(packed)
        fused_toks.append(out[:slots].tolist())
        fused_done.append(out[slots:].astype(bool).tolist())

    # reference loop (PR-3 semantics)
    cache = scattered()
    lengths, cur = lengths0, cur0
    live = {0: 1, 1: 1}                     # slot -> generated count
    key = jax.random.PRNGKey(4)
    ref_toks, ref_done = [], []
    for _ in range(max_new):
        key, k = jax.random.split(key)
        logits, cache = model.decode_step(params, cache, cur, lengths)
        t = np.asarray(sample(logits, k, temperature=0.0))
        lengths = lengths + jnp.asarray(
            [1 if s in live else 0 for s in range(slots)], jnp.int32)
        step_done = [False] * slots
        for s in list(live):
            live[s] += 1
            cur = cur.at[s, 0].set(int(t[s]))
            if live[s] >= max_new or int(lengths[s]) >= max_len - 1:
                step_done[s] = True
                del live[s]
        ref_toks.append(t.tolist())
        ref_done.append(step_done)

    # only active slots carry meaningful tokens
    for ft, rt, fd, rd in zip(fused_toks, ref_toks, fused_done, ref_done):
        assert fd == rd
        for s in (0, 1):
            assert ft[s] == rt[s]


# ---------------------------------------------------------------------------
# batched scatter == per-request reference scatter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_batched_scatter_matches_reference(built, mesh, fam):
    cfg, model, params = built[fam]
    rng = np.random.default_rng(4)
    slots, max_len, plen = 4, 32, 6
    toks = rng.integers(0, cfg.vocab_size, (2, plen)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(2, 8, cfg.d_model)) * 0.02, jnp.float32)
    _, pcache = model.prefill(params, batch, max_len=max_len)

    # request 0 -> slot 2, request 1 -> slot 0
    ref = model.init_cache(slots, max_len)
    ref = _scatter_cache(ref, pcache, 0, 2)
    ref = _scatter_cache(ref, pcache, 1, 0)
    got = batched_scatter(model.init_cache(slots, max_len), pcache,
                          jnp.asarray([True, False, True, False]),
                          jnp.asarray([1, 0, 0, 0], jnp.int32))
    for rl, gl in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(rl, np.float32),
                                      np.asarray(gl, np.float32))


# ---------------------------------------------------------------------------
# prompt-length ladder: padded prefill is exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_padded_prefill_matches_exact(built, mesh, fam):
    """Right-padding a prompt up the ladder with ``batch["lengths"]`` must
    reproduce the exact-length prefill bit-for-bit: logits, cache lengths,
    and the next decode step."""
    cfg, model, params = built[fam]
    rng = np.random.default_rng(5)
    L, Lb = 5, 8
    toks = rng.integers(0, cfg.vocab_size, (2, L)).astype(np.int32)
    padded = np.zeros((2, Lb), np.int32)
    padded[:, :L] = toks
    be = {"tokens": jnp.asarray(toks)}
    bp = {"tokens": jnp.asarray(padded),
          "lengths": jnp.asarray([L, L], jnp.int32)}
    if cfg.family == "encdec":
        fr = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)) * 0.02,
                         jnp.float32)
        be["frames"] = fr
        bp["frames"] = fr
    le, ce = model.prefill(params, be, max_len=32)
    lp, cp = model.prefill(params, bp, max_len=32)
    np.testing.assert_array_equal(np.asarray(le, np.float32),
                                  np.asarray(lp, np.float32))
    np.testing.assert_array_equal(np.asarray(ce["lengths"]),
                                  np.asarray(cp["lengths"]))
    t = jnp.argmax(le, -1).astype(jnp.int32)[:, None]
    l2e, _ = model.decode_step(params, ce, t, ce["lengths"])
    l2p, _ = model.decode_step(params, cp, t, cp["lengths"])
    np.testing.assert_array_equal(np.asarray(l2e, np.float32),
                                  np.asarray(l2p, np.float32))


def test_prompt_length_ladder_shape():
    lad = prompt_length_ladder(64)
    assert lad[-1] == 64 and lad[0] == 8
    assert all(b >= 2 * a for a, b in zip(lad, lad[1:]))
    assert bucket(5, ladder=lad) == 8
    assert bucket(9, ladder=lad) == 16
    assert bucket(64, ladder=lad) == 64
    assert bucket(100, ladder=lad) == 100      # above cap: exact, no pad
    assert prompt_length_ladder(6) == (6,)


def test_prefill_compiles_bounded_by_ladder(built, mesh):
    """Distinct prefill compilations track ladder rungs, not distinct
    prompt lengths: 6 different lengths land in 2 (batch, rung) shapes."""
    cfg, model, params = built["dense"]
    rng = np.random.default_rng(6)
    srv = _sim_server(model, mesh, slots=2, fused=True)
    for n in (3, 4, 5, 9, 11, 13):
        srv.submit(rng.integers(0, cfg.vocab_size, size=n), max_new_tokens=2)
    srv.run(params)
    assert len(srv.completed) == 6
    # bound: batch rungs {1,2} x ladder rungs {8,16} under this trace
    assert srv.prefill_compiles <= 4
    # reference engine compiles one shape per distinct length
    srv_ref = _sim_server(model, mesh, slots=2, fused=False)
    for n in (3, 4, 5, 9, 11, 13):
        srv_ref.submit(rng.integers(0, cfg.vocab_size, size=n),
                       max_new_tokens=2)
    srv_ref.run(params)
    assert srv_ref.prefill_compiles >= 6


# ---------------------------------------------------------------------------
# Pallas decode attention through the backend switch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 64])
def test_pallas_backend_parity_including_zero_lengths(window):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(3, 1, 4, 64)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(3, 256, 2, 64)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(3, 256, 2, 64)), jnp.float32)
    lengths = jnp.asarray([0, 17, 256], jnp.int32)     # incl. empty row
    ref = attention_decode(q, kc, vc, lengths, window=window)
    prev = set_attention_backend("pallas")
    try:
        got = attention_decode_auto(q, kc, vc, lengths, window=window)
    finally:
        set_attention_backend(prev)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # the zero-length row attends to nothing on both paths
    np.testing.assert_array_equal(np.asarray(ref[0]), 0.0)
    np.testing.assert_array_equal(np.asarray(got[0]), 0.0)


def test_pallas_backend_serving_stream_matches_jnp(built, mesh):
    """End-to-end: the same trace served with the Pallas decode-attention
    backend yields the same greedy token streams as the jnp path."""
    cfg, model, params = built["dense"]
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(3)]
    streams = {}
    for backend in ("jnp", "pallas"):
        prev = set_attention_backend(backend)
        try:
            srv = _sim_server(model, mesh, slots=2, fused=True)
            rids = [srv.submit(p, max_new_tokens=4) for p in prompts]
            srv.run(params)
        finally:
            set_attention_backend(prev)
        streams[backend] = [srv.completed[r].tokens for r in rids]
    assert streams["pallas"] == streams["jnp"]
    assert get_attention_backend() == "jnp"


# ---------------------------------------------------------------------------
# satellites: prefill AIMD budget, per-model completion telemetry
# ---------------------------------------------------------------------------

def test_prefill_aimd_budget_is_slo_fraction(built, mesh):
    cfg, model, params = built["dense"]
    srv = _sim_server(model, mesh, slots=2, slo=0.4, prefill_slo_frac=0.25)
    assert srv.admission.slo == pytest.approx(0.1)
    assert srv.slo == pytest.approx(0.4)


def test_per_model_completions_tagged(built, mesh):
    cfg, model, params = built["dense"]
    srv = _sim_server(model, mesh, slots=2, model_id="lm-a")
    rng = np.random.default_rng(9)
    for _ in range(5):
        srv.submit(rng.integers(0, cfg.vocab_size, size=8), max_new_tokens=3)
    srv.run(params)
    rep = srv.report()
    pm = rep["per_model"]["lm-a"]
    assert pm["completed"] == 5
    assert pm["latency_s"]["count"] == 5
    # the global series still carries every completion (dual emission)
    assert rep["queries"]["completed"] == 5
    assert rep["latency_s"]["count"] == 5
