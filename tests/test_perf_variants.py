"""Regression tests for the §Perf sharding variants — each runs the
optimized layout on a small multi-device mesh (subprocess with forced host
devices) and asserts numerical equivalence with the baseline layout."""

import subprocess
import sys
import textwrap

import pytest


def _run(code: str) -> str:
    import os
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    # keep the parent's platform pin: without it the subprocess probes for
    # TPUs (60 s stall + log noise) before falling back to host devices
    if os.environ.get("JAX_PLATFORMS"):
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)], capture_output=True,
        text=True, cwd="/root/repo", env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import ARCHITECTURES, reduced_config
from repro.models.api import build_model
from repro.distributed.sharding import serve_rules, train_rules
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
"""


def test_context_parallel_prefill_matches_tp():
    out = _run(PRELUDE + """
cfg = reduced_config(ARCHITECTURES["granite-8b"], num_layers=2, d_model=64)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32)
with mesh:
    rules = serve_rules(False)
    m1 = build_model(cfg, mesh, rules, q_block=16, k_block=16)
    params = m1.init(jax.random.PRNGKey(1))
    lg1, _ = jax.jit(lambda p, t: m1.prefill(p, {"tokens": t}))(params, toks)
    rules_cp = dict(rules); rules_cp["seq"] = "model"
    m2 = build_model(cfg, mesh, rules_cp, q_block=16, k_block=16)
    lg2, _ = jax.jit(lambda p, t: m2.prefill(p, {"tokens": t}))(params, toks)
err = float(jnp.max(jnp.abs(lg1.astype(jnp.float32) - lg2.astype(jnp.float32))))
assert err < 0.1, err
print("CP_OK", err)
""")
    assert "CP_OK" in out


def test_dp_major_train_matches_baseline():
    out = _run(PRELUDE + """
import dataclasses
cfg = reduced_config(ARCHITECTURES["dbrx-132b"], num_layers=2, d_model=64)
cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}
with mesh:
    rules = train_rules(False)
    m1 = build_model(cfg, mesh, rules)
    params = m1.init(jax.random.PRNGKey(1))
    l1 = jax.jit(m1.loss_fn)(params, batch)
    rules_dp = dict(rules)
    rules_dp.update(batch=("data", "model"), fsdp=("data",),
                    heads=None, kv_heads=None, ffn=None, vocab=None)
    m2 = build_model(cfg, mesh, rules_dp)
    l2 = jax.jit(m2.loss_fn)(params, batch)
assert abs(float(l1) - float(l2)) < 1e-2, (float(l1), float(l2))
print("DP_MAJOR_OK", float(l1), float(l2))
""")
    assert "DP_MAJOR_OK" in out


def test_moe_gather_mode_matches_2d():
    out = _run(PRELUDE + """
import dataclasses
cfg = reduced_config(ARCHITECTURES["kimi-k2-1t-a32b"], num_layers=2, d_model=64)
cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
with mesh:
    r2d = serve_rules(False, shard_experts_2d=True)
    m1 = build_model(cfg, mesh, r2d)
    params = m1.init(jax.random.PRNGKey(1))
    lg1, _ = jax.jit(lambda p, t: m1.prefill(p, {"tokens": t}))(params, toks)
    rg = serve_rules(False, shard_experts_2d=False); rg["fsdp"] = "data"
    m2 = build_model(cfg, mesh, rg)
    lg2, _ = jax.jit(lambda p, t: m2.prefill(p, {"tokens": t}))(params, toks)
err = float(jnp.max(jnp.abs(lg1.astype(jnp.float32) - lg2.astype(jnp.float32))))
assert err < 0.1, err
print("GATHER_OK", err)
""")
    assert "GATHER_OK" in out


def test_multi_pod_train_step_compiles_with_compression():
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs.base import ShapeSpec
from repro.configs.registry import ARCHITECTURES, reduced_config
from repro.launch.steps import build_train_step
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduced_config(ARCHITECTURES["granite-8b"], num_layers=2, d_model=64)
shape = ShapeSpec("t", 32, 8, "train")
with mesh:
    b = build_train_step(cfg, shape, mesh, num_microbatches=2)
    compiled = b.fn.lower(*b.arg_specs).compile()
txt = compiled.as_text()
assert "s16" in txt, "int16 compressed pod reduction missing from HLO"
print("POD_COMPRESS_OK")
""")
    assert "POD_COMPRESS_OK" in out
