"""Control plane (DESIGN.md §10): dynamic replica sets, autoscaling under a
flash crowd, SLO-aware admission control, heterogeneous routing, and the
deterministic ``repro.cluster.run`` driver — all exact oracles under the
virtual clock."""

import json

import numpy as np
import pytest

from repro.cluster import (ClusterPlan, LeastExpectedCompletion, SloAdmission,
                           cluster_scenario, least_loaded, run_plan,
                           run_plan_json)
from repro.core import metrics as M
from repro.core.batching import AIMDController, BatchQueue
from repro.core.containers import (JaxModelContainer, ReplicaSet,
                                   linear_latency)
from repro.core.frontend import make_clipper
from repro.core.interfaces import Query
from repro.workloads import poisson_trace, query_trace


def _fn(x):
    return np.zeros((len(x), 10), np.float32)


def _container(mid="m", base=0.002, per_item=1e-4, seed=0):
    return JaxModelContainer(mid, _fn, latency_model=linear_latency(
        base, per_item, rng=np.random.default_rng(seed)))


def _rs(n=2, **kw):
    return ReplicaSet([_container(seed=i, **kw) for i in range(n)],
                      lambda: AIMDController(0.02))


# ---------------------------------------------------------------------------
# dynamic ReplicaSet: add / retire / drain
# ---------------------------------------------------------------------------

def test_add_replica_grows_live_set_and_attaches_metrics():
    rs = _rs(1)
    reg = M.MetricsRegistry(0.02)
    rs.attach_metrics(reg)
    assert rs.n_live == 1
    ri = rs.add_replica(_container(seed=9), now=1.5)
    assert ri == 1 and rs.n_live == 2
    assert rs.free_at[1] == 1.5
    assert rs.queues[1].metrics is reg and rs.queues[1].model_id == "m"


def test_retire_requeues_backlog_and_preserves_inflight():
    rs = _rs(2)
    q1 = Query(1, np.zeros(4), 0, 0.0, deadline=0.02)
    q2 = Query(2, np.zeros(4), 0, 0.001, deadline=0.021)
    rs.queues[1].put(q1)
    rs.queues[1].put(q2)
    rs.free_at[1] = 0.5                      # replica 1 mid-batch (in flight)
    rs.retire_replica(1, now=0.0)
    # backlog moved, nothing dropped; new work no longer routes there
    assert len(rs.queues[1]) == 0 and len(rs.queues[0]) == 2
    assert rs.routable() == [0]
    # the in-flight batch has not completed: slot still draining, not reaped
    assert rs.draining[1] and not rs.retired[1]
    rs.reap(0.4)
    assert not rs.retired[1]                 # still busy at t=0.4
    rs.reap(0.5)
    assert rs.retired[1] and not rs.draining[1]
    # indices stay valid for in-flight completion events: slot never reused
    assert len(rs.replicas) == 2


def test_retire_last_live_replica_refused():
    rs = _rs(1)
    with pytest.raises(ValueError):
        rs.retire_replica(0, now=0.0)
    # the refused call must not leave the replica wedged in draining state
    assert rs.routable() == [0] and not rs.draining[0]


def test_requeue_merges_by_arrival_order():
    make = lambda: BatchQueue(AIMDController(0.02))
    a, b = make(), make()
    a.put(Query(1, 0, 0, 0.3))
    b.put(Query(2, 0, 0, 0.1))
    b.put(Query(3, 0, 0, 0.5))
    moved = a.requeue_to(b)
    assert moved == 1 and len(a) == 0
    assert [q.query_id for q in b._q] == [2, 1, 3]


# ---------------------------------------------------------------------------
# satellite: linear_latency default streams are decorrelated
# ---------------------------------------------------------------------------

def test_linear_latency_default_streams_independent():
    a = linear_latency(0.001, 0.0, jitter=0.5)
    b = linear_latency(0.001, 0.0, jitter=0.5)
    assert [a(1) for _ in range(8)] != [b(1) for _ in range(8)]
    # explicit rngs with one seed still produce identical streams
    c = linear_latency(0.001, 0.0, jitter=0.5, rng=np.random.default_rng(4))
    d = linear_latency(0.001, 0.0, jitter=0.5, rng=np.random.default_rng(4))
    assert [c(1) for _ in range(8)] == [d(1) for _ in range(8)]


# ---------------------------------------------------------------------------
# heterogeneous routing
# ---------------------------------------------------------------------------

def _hetero_clipper(router):
    fast = JaxModelContainer("m", _fn, latency_model=linear_latency(
        0.001, 1e-4, rng=np.random.default_rng(1)))
    slow = JaxModelContainer("m", _fn, latency_model=linear_latency(
        0.010, 1e-3, rng=np.random.default_rng(2)))
    rs = ReplicaSet([fast, slow], lambda: AIMDController(0.02))
    from repro.core.frontend import Clipper
    from repro.core.selection import Exp4Policy
    clip = Clipper({"m": rs}, Exp4Policy(["m"]), slo=0.02, use_cache=False,
                   router=router)
    return clip, fast, slow


def test_lect_router_prefers_fast_replica():
    trace = query_trace(poisson_trace(400.0, 1.0, seed=5), seed=5, pool=0)
    lect_clip, lect_fast, lect_slow = _hetero_clipper(
        LeastExpectedCompletion())
    lect_clip.replay(trace)
    ll_clip, ll_fast, ll_slow = _hetero_clipper(least_loaded)
    ll_clip.replay(trace)
    # least-loaded splits ~evenly over the heterogeneous pair; LECT shifts
    # work onto the fast replica and wins the tail
    assert lect_fast.stats.queries > lect_slow.stats.queries
    assert lect_fast.stats.queries > ll_fast.stats.queries
    p99_lect = lect_clip.report()["latency_s"]["p99"]
    p99_ll = ll_clip.report()["latency_s"]["p99"]
    assert p99_lect < p99_ll


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_shed_under_overload_bounds_tail():
    over = cluster_scenario("poisson", rate=1500.0, duration=1.0)
    shed = run_plan(ClusterPlan(scenario=over, autoscale=False,
                                admission="shed"))
    noadm = run_plan(ClusterPlan(scenario=over, autoscale=False))
    assert shed["admission"]["shed"] > 0
    assert (shed["queries"]["completed"] + shed["admission"]["shed"]
            == shed["queries"]["submitted"])
    # early shedding keeps the *served* tail far below the collapse the
    # un-protected run suffers
    assert shed["latency_s"]["p99"] < noadm["latency_s"]["p99"] / 5
    # sheds count against attainment — the controller can't game the metric
    assert shed["slo"]["attainment"] <= (
        shed["queries"]["completed"] / shed["queries"]["submitted"])


def test_shed_qids_partition_results():
    """Every submitted qid lands in exactly one of results / shed_qids, so
    callers can tell a shed query from a pending one."""
    clip = make_clipper(
        {"m": _fn}, "exp4", slo=0.020, use_cache=False,
        latency_models={"m": linear_latency(0.004, 4e-3,
                                            rng=np.random.default_rng(0))},
        admission=SloAdmission(policy="shed"))
    trace = query_trace(poisson_trace(1500.0, 0.5, seed=1), seed=1, pool=0)
    qids = clip.replay(trace)
    assert clip.shed_qids                        # overload: some were shed
    assert clip.shed_qids.isdisjoint(clip.results)
    assert set(qids) == clip.shed_qids | set(clip.results)
    assert len(clip.shed_qids) == clip.metrics.counter(M.QUERIES_SHED)


def test_admission_degrade_drops_slow_model_only():
    adm = SloAdmission(policy="degrade")
    clip = make_clipper(
        {"fast": _fn, "slow": _fn}, "exp4", slo=0.020, use_cache=False,
        latency_models={
            "fast": linear_latency(0.002, 1e-4,
                                   rng=np.random.default_rng(1)),
            "slow": linear_latency(0.060, 1e-3,
                                   rng=np.random.default_rng(2))},
        admission=adm)
    trace = query_trace(poisson_trace(300.0, 1.0, seed=3), seed=3, pool=0)
    clip.replay(trace)
    rep = clip.report()
    # the 60 ms model can never meet a 20 ms deadline: once its service
    # stats exist, every query degrades to the fast model and completes
    assert rep["admission"]["degraded"] > 0
    assert rep["admission"]["shed"] == 0
    assert rep["queries"]["completed"] == rep["queries"]["submitted"]
    assert rep["slo"]["violations"] == 0


# ---------------------------------------------------------------------------
# the acceptance oracle: autoscaled flash crowd vs fixed baseline
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def flash_crowd_runs():
    sc = cluster_scenario("flash_crowd")
    auto = run_plan(ClusterPlan(scenario=sc, autoscale=True))
    fixed = run_plan(ClusterPlan(scenario=sc, autoscale=False))
    return sc, auto, fixed


def test_autoscaler_beats_fixed_baseline_at_equal_steady_state(
        flash_crowd_runs):
    sc, auto, fixed = flash_crowd_runs
    # equal steady-state provisioning: both runs start (and the autoscaled
    # one ends) at the scenario's replica count
    assert sc.replicas == 1
    assert auto["scenario"]["replicas"] == fixed["scenario"]["replicas"] == 1
    assert auto["slo"]["attainment"] > fixed["slo"]["attainment"]
    # same offered load on both runs
    assert auto["queries"]["submitted"] == fixed["queries"]["submitted"]


def test_autoscaler_scales_up_then_back_down(flash_crowd_runs):
    _, auto, _ = flash_crowd_runs
    a = auto["cluster"]["autoscalers"][0]
    assert a["peak_live"] > 1                 # grew into the burst
    assert a["live"] == 1                     # drained back after it
    assert a["added"] >= a["peak_live"] - 1
    assert a["retired"] == a["added"]         # every scale-up was unwound
    # the timeline must actually visit the peak and return
    lives = [live for _, live in a["timeline"]]
    assert max(lives) == a["peak_live"] and lives[-1] == 1
    # drained replicas never lose work: everything submitted completes
    assert auto["queries"]["completed"] == auto["queries"]["submitted"]


def test_autoscaled_report_byte_identical(flash_crowd_runs):
    sc, auto, _ = flash_crowd_runs
    again = run_plan(ClusterPlan(scenario=sc, autoscale=True))
    assert (json.dumps(auto, sort_keys=True)
            == json.dumps(again, sort_keys=True))


# ---------------------------------------------------------------------------
# driver CLI + report provenance
# ---------------------------------------------------------------------------

def test_cluster_cli_report_out_and_meta(tmp_path):
    from repro.cluster.run import main
    out = tmp_path / "rep.json"
    rc = main(["--scenario", "flash_crowd", "--seed", "3", "--duration",
               "0.5", "--report-out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["schema"] == "repro.metrics/v1"
    assert rep["meta"] == {"trace_seed": 3,
                           "trace_generator": "flash_crowd_trace"}
    assert rep["cluster"]["plan"]["autoscale"] is True
    assert {"shed", "degraded", "shed_rate"} == set(rep["admission"])


def test_workloads_cli_report_out_flag(tmp_path):
    from repro.workloads.run import main
    out = tmp_path / "rep.json"
    rc = main(["--scenario", "poisson", "--duration", "0.2",
               "--report-out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["meta"]["trace_generator"] == "poisson_trace"
    assert rep["meta"]["trace_seed"] == rep["scenario"]["seed"]


def test_run_plan_json_deterministic_lmserver():
    sc = cluster_scenario("poisson", duration=0.05, rate=200.0, lm_requests=4,
                          slots=2, prompt_len=4, max_new_tokens=2)
    plan = ClusterPlan(scenario=sc, stack="lmserver", admission="shed")
    assert run_plan_json(plan) == run_plan_json(plan)
