"""LM serving engine: continuous batching, admission control, completion."""

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHITECTURES, reduced_config
from repro.distributed.sharding import serve_rules
from repro.models.api import build_model
from repro.serving.engine import LMServer
from repro.launch.mesh import compat_make_mesh


@pytest.fixture(scope="module")
def mesh():
    return compat_make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def served(mesh):
    cfg = reduced_config(ARCHITECTURES["smollm-360m"])
    model = build_model(cfg, mesh, serve_rules(False))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_all_requests_complete(served, mesh):
    cfg, model, params = served
    srv = LMServer(model, mesh, serve_rules(False), slots=4, max_len=64)
    rng = np.random.default_rng(0)
    rids = [srv.submit(rng.integers(0, cfg.vocab_size, size=8),
                       max_new_tokens=5) for _ in range(9)]
    srv.run(params)
    assert len(srv.completed) == 9
    for rid in rids:
        assert len(srv.completed[rid].tokens) == 5


def test_greedy_decode_deterministic(served, mesh):
    cfg, model, params = served
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=8)
    outs = []
    for _ in range(2):
        srv = LMServer(model, mesh, serve_rules(False), slots=2, max_len=64,
                       temperature=0.0)
        rid = srv.submit(prompt, max_new_tokens=6)
        srv.run(params)
        outs.append(srv.completed[rid].tokens)
    assert outs[0] == outs[1]


def test_continuous_batching_mixes_requests(served, mesh):
    """Late-arriving requests join while earlier ones still decode."""
    cfg, model, params = served
    srv = LMServer(model, mesh, serve_rules(False), slots=4, max_len=64)
    rng = np.random.default_rng(2)
    first = srv.submit(rng.integers(0, cfg.vocab_size, size=8),
                       max_new_tokens=12)
    srv.step(params)    # admit + decode once
    late = srv.submit(rng.integers(0, cfg.vocab_size, size=8),
                      max_new_tokens=3)
    srv.run(params)
    assert srv.completed[late].tokens and srv.completed[first].tokens
    assert len(srv.completed[first].tokens) == 12


def test_varied_prompt_lengths(served, mesh):
    cfg, model, params = served
    srv = LMServer(model, mesh, serve_rules(False), slots=4, max_len=64)
    rng = np.random.default_rng(3)
    rids = [srv.submit(rng.integers(0, cfg.vocab_size, size=n),
                       max_new_tokens=4)
            for n in (4, 8, 4, 16)]
    srv.run(params)
    assert len(srv.completed) == 4
