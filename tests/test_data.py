"""Data pipeline: determinism, seekability, host sharding."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ShapeSpec
from repro.configs.registry import ARCHITECTURES, reduced_config
from repro.data.pipeline import SyntheticLMData

CFG = reduced_config(ARCHITECTURES["smollm-360m"])
SHAPE = ShapeSpec("t", 32, 8, "train")


def test_deterministic_across_instances():
    a = SyntheticLMData(CFG, SHAPE, seed=1).batch_at(7)
    b = SyntheticLMData(CFG, SHAPE, seed=1).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_seekable_restart_consistency():
    """batch_at(k) equals the k-th element of an iterator from 0, and of an
    iterator resumed at k (bit-exact restart requirement)."""
    ds = SyntheticLMData(CFG, SHAPE, seed=3)
    it = ds.iterator(0)
    for _ in range(4):
        next(it)
    from_iter = next(it)                     # element 4
    np.testing.assert_array_equal(from_iter["tokens"],
                                  ds.batch_at(4)["tokens"])
    resumed = next(ds.iterator(4))
    np.testing.assert_array_equal(resumed["labels"],
                                  ds.batch_at(4)["labels"])


def test_steps_differ():
    ds = SyntheticLMData(CFG, SHAPE, seed=0)
    assert not np.array_equal(ds.batch_at(0)["tokens"],
                              ds.batch_at(1)["tokens"])


def test_host_sharding_partitions_batch():
    full = SyntheticLMData(CFG, SHAPE, seed=0, num_hosts=1).batch_at(0)
    h0 = SyntheticLMData(CFG, SHAPE, seed=0, num_hosts=2, host_id=0).batch_at(0)
    h1 = SyntheticLMData(CFG, SHAPE, seed=0, num_hosts=2, host_id=1).batch_at(0)
    assert h0["tokens"].shape[0] == full["tokens"].shape[0] // 2
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_labels_are_shifted_tokens():
    b = SyntheticLMData(CFG, SHAPE, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@given(st.integers(0, 1000), st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_tokens_in_vocab(step, seed):
    b = SyntheticLMData(CFG, SHAPE, seed=seed).batch_at(step)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < CFG.vocab_size


def test_frontend_stub_batches():
    vcfg = reduced_config(ARCHITECTURES["internvl2-1b"])
    b = SyntheticLMData(vcfg, SHAPE, seed=0).batch_at(0)
    assert "prefix_embeddings" in b
    assert b["prefix_embeddings"].shape == (8, vcfg.num_prefix_embeddings,
                                            vcfg.d_model)
    ecfg = reduced_config(ARCHITECTURES["seamless-m4t-medium"])
    b = SyntheticLMData(ecfg, SHAPE, seed=0).batch_at(0)
    assert b["frames"].shape == (8, SHAPE.seq_len, ecfg.d_model)
    assert b["tokens"].shape[1] == SHAPE.seq_len // ecfg.decoder_ratio
