"""Prediction pipelines (DESIGN.md §12): graph spec validation, the
deadline splitter's InferLine properties, DAG/cascade execution on the
Clipper frontend, the intermediate-result cache, per-stage control-plane
integration, and the LM draft-then-verify cascade — exact oracles under the
virtual clock."""

import dataclasses
import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import metrics as M
from repro.core.containers import linear_latency
from repro.core.frontend import _default_loss, make_clipper
from repro.pipeline import (CASCADE_THRESHOLD, PipelineExecutor,
                            PipelineGraph, Stage, build_executor,
                            cascade_graph, distinct_token_confidence,
                            fanout_graph, make_escalate, pipeline_models,
                            pipeline_scenario, run_lmcascade, run_pipeline,
                            split_slo)
from repro.workloads import poisson_trace, query_trace
from repro.workloads.scenario import D_FEAT, SCENARIOS


def _sc(**kw):
    return pipeline_scenario(**{"duration": 0.3, **kw})


# ---------------------------------------------------------------------------
# graph spec
# ---------------------------------------------------------------------------

def test_graph_validation():
    with pytest.raises(ValueError, match="unknown parent"):
        PipelineGraph([Stage("a", ("m",), parents=("ghost",))])
    with pytest.raises(ValueError, match="duplicate"):
        PipelineGraph([Stage("a", ("m",)), Stage("a", ("m",))])
    with pytest.raises(ValueError, match="cycle"):
        PipelineGraph([Stage("a", ("m",), parents=("b",)),
                       Stage("b", ("m",), parents=("a",))])
    with pytest.raises(ValueError, match="output"):
        PipelineGraph([Stage("a", ("m",)), Stage("b", ("m",))])


def test_topo_order_and_shape():
    g = cascade_graph(("cheap0", "cheap1"), "accurate",
                      preprocess_model="prep")
    assert g.order.index("prep") < g.order.index("draft")
    assert g.order.index("draft") < g.order.index("verify")
    assert g.output == "output"
    assert g.model_ids() == ["prep", "cheap0", "cheap1", "accurate"]
    d = g.describe()
    assert [s["name"] for s in d["stages"]] == g.order
    assert any(s["gated"] for s in d["stages"])


# ---------------------------------------------------------------------------
# deadline splitter: the InferLine properties (satellite)
# ---------------------------------------------------------------------------

def _chain(n):
    return PipelineGraph(
        [Stage(f"s{i}", (f"m{i}",),
               parents=((f"s{i-1}",) if i else ()))
         for i in range(n)])


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(min_value=1e-6, max_value=1.0),
                min_size=1, max_size=6),
       st.floats(min_value=1e-3, max_value=10.0))
def test_split_path_sums_to_slo(ests, slo):
    g = _chain(len(ests))
    split = split_slo(g, slo, {f"s{i}": e for i, e in enumerate(ests)})
    # a chain IS the critical path: shares sum to exactly the SLO and the
    # prefixes are the running sums, ending at the SLO
    assert sum(split.shares.values()) == pytest.approx(slo)
    assert split.prefix[g.output] == pytest.approx(slo)
    acc = 0.0
    for i in range(len(ests)):
        acc += split.shares[f"s{i}"]
        assert split.prefix[f"s{i}"] == pytest.approx(acc)
    assert all(s > 0 for s in split.shares.values())


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(min_value=1e-5, max_value=1.0),
                min_size=2, max_size=5),
       st.integers(min_value=0, max_value=4),
       st.floats(min_value=1.1, max_value=10.0))
def test_split_monotone_in_service_time(ests, idx, factor):
    idx = idx % len(ests)
    g = _chain(len(ests))
    est = {f"s{i}": e for i, e in enumerate(ests)}
    before = split_slo(g, 1.0, est)
    est[f"s{idx}"] *= factor
    after = split_slo(g, 1.0, est)
    # growing one stage's service estimate never shrinks its share, and
    # every path still fits inside the SLO
    assert after.shares[f"s{idx}"] >= before.shares[f"s{idx}"] - 1e-12
    assert sum(after.shares.values()) <= 1.0 + 1e-9


def test_split_diamond_paths_within_slo():
    g = PipelineGraph([
        Stage("a", ("m0",)),
        Stage("fast", ("m1",), parents=("a",)),
        Stage("slow", ("m2",), parents=("a",)),
        Stage("out", ("m3",), parents=("fast", "slow")),
    ])
    split = split_slo(g, 0.1, {"a": 1e-3, "fast": 1e-4, "slow": 5e-3,
                               "out": 1e-3})
    for path in (("a", "fast", "out"), ("a", "slow", "out")):
        assert sum(split.shares[s] for s in path) <= 0.1 + 1e-9
    # the critical path (through 'slow') uses the whole budget
    assert (split.shares["a"] + split.shares["slow"] + split.shares["out"]
            == pytest.approx(0.1))


# ---------------------------------------------------------------------------
# execution: cascade + fanout on the frontend
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cascade_run():
    sc = _sc()
    return sc, run_pipeline(sc, "cascade")


def test_cascade_completes_everything(cascade_run):
    _, rep = cascade_run
    assert rep["queries"]["submitted"] > 0
    assert rep["queries"]["completed"] == rep["queries"]["submitted"]
    p = rep["pipeline"]
    # every query took exactly one gate decision on the verify stage
    assert (p["escalations"] + p["stages_skipped"]
            == rep["queries"]["submitted"])
    assert 0.0 < p["escalation_rate"] < 1.0
    assert p["stage_jobs"] > rep["queries"]["submitted"]


def test_cascade_escalates_only_low_confidence(cascade_run):
    sc, _ = cascade_run
    ex = build_executor(sc)
    trace = query_trace(sc.arrival_times(), sc.seed, d_feat=D_FEAT,
                        pool=sc.pool)
    pids = ex.replay(trace)
    assert set(pids) == set(ex.results)
    for pred in ex.results.values():
        y = pred.y
        assert set(y) == {"y", "confidence", "escalated"}
        if y["escalated"]:
            assert pred.confidence == 1.0      # verify answered
        else:
            assert y["confidence"] >= CASCADE_THRESHOLD
        assert y["y"].shape == (10,)


def test_cascade_report_deterministic(cascade_run):
    sc, rep = cascade_run
    again = run_pipeline(sc, "cascade")
    assert (json.dumps(rep, sort_keys=True)
            == json.dumps(again, sort_keys=True))


def test_fanout_graph_runs_all_branches():
    sc = _sc(pool=0)
    rep = run_pipeline(sc, "fanout")
    n = rep["queries"]["submitted"]
    assert rep["queries"]["completed"] == n
    # no gates in the fanout shape: every branch model sees every query
    assert rep["pipeline"]["stages_skipped"] == 0
    for mid in ("cheap0", "cheap1", "accurate"):
        pm = rep["per_model"][mid]
        assert pm["cache"]["hits"] + pm["cache"]["misses"] == n


def test_pure_combine_stage_and_default_prepare():
    # minimal DAG exercised without the scenario zoo: root model -> pure
    # combine output stage; ndarray pass-through prepare
    calls = []

    def fn(x):
        calls.append(len(x))
        return np.asarray(x, np.float32) * 2.0

    g = PipelineGraph([
        Stage("root", ("m",)),
        Stage("out", parents=("root",),
              combine=lambda xin, preds, outs: {"y": outs["root"] + 1.0}),
    ])
    ex = PipelineExecutor(g, {"m": fn}, slo=0.05, use_cache=False)
    pid = ex.submit(np.ones(4, np.float32), arrival_time=0.0)
    ex.run()
    np.testing.assert_allclose(ex.results[pid].y["y"], np.full(4, 3.0))


# ---------------------------------------------------------------------------
# intermediate-result cache (tentpole part 3 + cache satellite)
# ---------------------------------------------------------------------------

def test_intermediate_cache_shares_prefixes_across_queries():
    sc = _sc(pool=16)                   # heavy skew: few unique queries
    rep = run_pipeline(sc, "cascade")
    n = rep["queries"]["submitted"]
    assert rep["cache"]["hit_rate"] > 0.5
    # per-model cache counters (satellite): exposed per stage model, and
    # consistent with the global pair
    per_model = rep["per_model"]
    for mid in ("prep", "cheap0", "cheap1", "accurate"):
        c = per_model[mid]["cache"]
        assert set(c) == {"hits", "misses", "hit_rate"}
        assert c["hits"] + c["misses"] <= n
    assert (sum(per_model[m]["cache"]["hits"] for m in per_model)
            == rep["cache"]["hits"])
    # a cached prefix skips the model: prep evaluated far fewer times than
    # queries submitted
    assert per_model["prep"]["queries"] < n


def test_cache_disabled_pays_full_price():
    sc = _sc(pool=16)
    hot = run_pipeline(sc, "cascade")
    cold = run_pipeline(sc, "cascade", use_cache=False)
    assert cold["cache"]["hits"] == 0
    cost = lambda r: sum(pm["service_s"]["sum"] or 0.0
                         for pm in r["per_model"].values())
    assert cost(cold) > cost(hot)


def test_cross_pipeline_cache_sharing():
    """Two pipelines over one executor-grade cache: the fanout pipeline's
    prep/cheap stages reuse results the cascade pipeline already computed
    (same model ids, same stage inputs -> same keys)."""
    sc = _sc(pool=8)
    models, lat, priors, _ = pipeline_models(sc)
    kw = dict(slo=sc.slo, latency_models=lat, service_priors=priors,
              seed=sc.seed)
    trace = query_trace(sc.arrival_times(), sc.seed, d_feat=D_FEAT,
                        pool=sc.pool)
    ex1 = PipelineExecutor(cascade_graph(("cheap0", "cheap1"), "accurate",
                                         preprocess_model="prep"),
                           models, **kw)
    ex1.replay(trace)
    # second pipeline shape, *sharing the first executor's Clipper cache*
    ex2 = PipelineExecutor(fanout_graph(("cheap0", "cheap1"),
                                        preprocess_model="prep"),
                           models, **kw)
    # share the underlying entry store (each executor keeps its own
    # telemetry registry, so ex2's hits are counted in ex2's report)
    ex2.clip.cache.cache = ex1.clip.cache.cache
    ex2.replay(trace)
    rep2 = ex2.report()
    # every prep/cheap evaluation the cascade warmed is a fanout hit
    assert rep2["per_model"]["prep"]["cache"]["hit_rate"] == 1.0


# ---------------------------------------------------------------------------
# stage deadlines feed admission; stage shares feed AIMD
# ---------------------------------------------------------------------------

def test_stage_aimd_budgets_follow_split():
    sc = _sc()
    ex = build_executor(sc)
    for mid, rs in ex.replica_sets.items():
        share = ex.split.shares[ex.stage_of[mid]]
        assert rs.queues[0].controller.slo == pytest.approx(share)
    # replan from live stats repoints every controller
    trace = query_trace(sc.arrival_times(), sc.seed, d_feat=D_FEAT,
                        pool=sc.pool)
    ex.replay(trace)
    assert ex.replans >= 1
    for mid, rs in ex.replica_sets.items():
        assert rs.queues[0].controller.slo == pytest.approx(
            ex.split.shares[ex.stage_of[mid]])
    # the accurate stage is the hot one: its share dominates the split
    assert (ex.split.shares[ex.stage_of["accurate"]]
            > ex.split.shares[ex.stage_of["prep"]])


def test_pipeline_admission_sheds_by_stage_deadline():
    from repro.cluster import SloAdmission
    sc = _sc(rate=2000.0, pool=0, duration=0.4)       # way past saturation
    ex = build_executor(sc, admission=SloAdmission(policy="shed"))
    trace = query_trace(sc.arrival_times(), sc.seed, d_feat=D_FEAT, pool=0)
    pids = ex.replay(trace)
    rep = ex.report()
    assert rep["admission"]["shed"] > 0
    # a pipeline query either produced an answer or was shed, never both —
    # and ``admission.shed`` is pipeline-granular (stage-level admission
    # actions are re-scoped to pipeline.stages_shed), so the completed +
    # shed partition of submitted holds like every other stack
    assert ex.shed_qids.isdisjoint(ex.results)
    assert set(pids) == ex.shed_qids | set(ex.results)
    assert rep["admission"]["shed"] == len(ex.shed_qids)
    assert (rep["queries"]["completed"] + rep["admission"]["shed"]
            == rep["queries"]["submitted"])
    assert rep["pipeline"]["stages_shed"] >= rep["admission"]["shed"]
    # stage-level shedding bounds the served tail: survivors stay sane
    assert rep["latency_s"]["p99"] < 10 * sc.slo


# ---------------------------------------------------------------------------
# control plane: per-stage provisioning + retire-during-flight (satellite)
# ---------------------------------------------------------------------------

def test_cluster_pipeline_stack_provisions_stages_independently():
    from repro.cluster import ClusterPlan, run_plan
    sc = dataclasses.replace(SCENARIOS["pipeline"], duration=1.0,
                             rate=700.0, pool=0)
    rep = run_plan(ClusterPlan(scenario=sc, stack="pipeline",
                               autoscale=True))
    assert rep["queries"]["completed"] == rep["queries"]["submitted"]
    peaks = {a["model"]: a["peak_live"]
             for a in rep["cluster"]["autoscalers"]}
    assert set(peaks) == {"prep", "cheap0", "cheap1", "accurate"}
    # the expensive verify tier grew more than the cheap root tier
    assert peaks["accurate"] > peaks["prep"]
    again = run_plan(ClusterPlan(scenario=sc, stack="pipeline",
                                 autoscale=True))
    assert (json.dumps(rep, sort_keys=True)
            == json.dumps(again, sort_keys=True))


def test_retire_replica_during_pipeline_flight():
    """Retiring a stage replica while pipeline stage jobs are in flight
    must not invalidate their completion events: backlog requeues, the
    in-flight batch lands on the original (never-reused) slot index, and
    every pipeline query still completes."""
    from repro.pipeline import pipeline_replica_factory
    sc = _sc(pool=0, rate=400.0)
    ex = build_executor(sc)
    factory = pipeline_replica_factory(sc, pipeline_models(sc)[0])
    for mid in ex.replica_sets:
        ex.replica_sets[mid].add_replica(factory(mid), now=0.0)
    trace = query_trace(sc.arrival_times(), sc.seed, d_feat=D_FEAT, pool=0)
    n_half = len(trace) // 2
    pids = []
    for at, x, _ in trace[:n_half]:
        ex.run(until=at)
        pids.append(ex.submit(x, arrival_time=at))
    # mid-flight: events pending, queues non-empty; retire replica 0 of
    # every stage model
    assert ex.pending
    for mid, rs in ex.replica_sets.items():
        rs.retire_replica(0, now=ex.now)
        assert rs.routable() == [1]
    for at, x, _ in trace[n_half:]:
        ex.run(until=at)
        pids.append(ex.submit(x, arrival_time=at))
    ex.run()
    assert not ex.pending
    assert set(pids) == set(ex.results)        # nothing lost or stuck
    rep = ex.report()
    assert rep["queries"]["completed"] == len(trace)
    for rs in ex.replica_sets.values():
        rs.reap(ex.now)      # the autoscaler tick normally does this
        assert rs.retired[0] and not rs.draining[0]


# ---------------------------------------------------------------------------
# LM cascade (draft-then-verify)
# ---------------------------------------------------------------------------

def test_distinct_token_confidence():
    assert distinct_token_confidence([]) == 0.0
    assert distinct_token_confidence([1, 2, 3, 4]) == 1.0
    assert distinct_token_confidence([7, 7, 7, 7]) == pytest.approx(0.25)
    esc = make_escalate(0.9)
    Req = type("R", (), {})
    r = Req(); r.tokens = [1, 1, 2]
    assert esc(r)
    r2 = Req(); r2.tokens = [1, 2, 3]
    assert not esc(r2)


@pytest.mark.parametrize("threshold,expect", [(0.0, 0), (1.5, None)])
def test_lmcascade_escalation_extremes(threshold, expect):
    sc = _sc(lm_requests=6, max_new_tokens=4)
    rep = run_lmcascade(sc, threshold=threshold)
    n = rep["queries"]["submitted"]
    assert rep["queries"]["completed"] == n
    if expect is None:
        expect = n                       # threshold > 1: everything escalates
    assert rep["cascade"]["escalated"] == expect
    assert rep["cascade"]["verify"]["queries"]["submitted"] == expect
    assert rep["cascade"]["draft"]["queries"]["submitted"] == n
    # escalated requests pay both tiers: end-to-end latency dominates the
    # draft tier's own per-request latency
    if expect == n:
        assert (rep["latency_s"]["mean"]
                > rep["cascade"]["draft"]["latency_s"]["mean"])


class _AlwaysShed:
    def admit_lm(self, srv, now):
        return False


def test_lmcascade_verify_shed_degrades_to_draft():
    """An escalated request whose verify tier sheds it keeps the draft
    answer (degraded), and a draft-tier shed is a cascade-level shed —
    requests are never silently lost."""
    sc = _sc(lm_requests=6, max_new_tokens=4)
    rep = run_lmcascade(sc, threshold=1.5,       # everything escalates...
                        verify_admission=_AlwaysShed())
    n = rep["queries"]["submitted"]
    assert rep["queries"]["completed"] == n      # ...but nothing is lost
    assert rep["admission"]["degraded"] == n
    assert rep["cascade"]["verify"]["queries"]["completed"] == 0
    shed = run_lmcascade(sc, draft_admission=_AlwaysShed())
    assert shed["queries"]["completed"] == 0
    assert shed["admission"]["shed"] == shed["queries"]["submitted"]


def test_lmcascade_deterministic():
    sc = _sc(lm_requests=8, max_new_tokens=8)
    a, b = run_lmcascade(sc), run_lmcascade(sc)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    assert 0 < a["cascade"]["escalated"] < a["queries"]["submitted"]


# ---------------------------------------------------------------------------
# satellites: _default_loss on structured predictions; CLI; bench contract
# ---------------------------------------------------------------------------

def test_default_loss_handles_structured_predictions():
    scores = np.asarray([0.1, 0.7, 0.2])
    assert _default_loss({"y": scores, "confidence": 0.5}, 1) == 0.0
    assert _default_loss({"y": scores, "confidence": 0.5}, 2) == 1.0
    assert _default_loss((scores, 0.9), 1) == 0.0
    assert _default_loss({"a": (scores, 1)}, 1) == 0.0   # nested, no 'y' key
    assert _default_loss(scores, 1) == 0.0               # plain still works
    assert _default_loss(0.25, 0.5) == pytest.approx(0.25)
    with pytest.raises(ValueError):
        _default_loss({}, 1)
    with pytest.raises(ValueError):
        _default_loss((), 1)


def test_feedback_loop_with_pipeline_style_models():
    """A frontend whose containers emit structured predictions survives the
    feedback join (the _default_loss fix, end to end)."""
    from repro.core.interfaces import Feedback

    def structured(x):
        return [{"y": np.asarray([1.0, 0.0]), "confidence": 1.0}
                for _ in range(len(x))]

    clip = make_clipper(
        {"m": structured}, "exp4", slo=0.02,
        latency_models={"m": linear_latency(
            0.001, 1e-5, rng=np.random.default_rng(0))})
    x = np.ones(4, np.float32)
    clip.submit(x, arrival_time=0.0)
    clip.run()
    clip.feedback(Feedback(0, x, 0))           # must not raise


def test_late_query_renders_on_first_arrival_past_deadline():
    """Deadline fires with zero predictions: the first model to return
    renders a partial answer immediately — the query (or pipeline stage)
    must not wait out the remaining stragglers."""
    clip = make_clipper(
        {"a": lambda x: np.zeros((len(x), 10), np.float32),
         "b": lambda x: np.zeros((len(x), 10), np.float32)},
        "exp4", slo=0.02, use_cache=False,
        latency_models={
            "a": linear_latency(0.05, 0.0, rng=np.random.default_rng(1)),
            "b": linear_latency(5.0, 0.0, rng=np.random.default_rng(2))})
    qid = clip.submit(np.ones(4, np.float32), arrival_time=0.0)
    clip.run(until=1.0)                 # model b would only land at t=5
    pred = clip.results[qid]
    assert pred.missing_models == ("b",)
    assert pred.latency == pytest.approx(0.05)


def test_pipeline_cli_report_out_and_meta(tmp_path):
    from repro.pipeline.run import main
    out = tmp_path / "rep.json"
    rc = main(["--scenario", "cascade", "--seed", "3", "--duration", "0.2",
               "--report-out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["schema"] == "repro.metrics/v1"
    assert rep["stack"] == "pipeline"
    assert rep["meta"] == {"trace_seed": 3,
                           "trace_generator": "poisson_trace"}
    assert rep["pipeline"]["graph"]["output"] == "output"
    assert rep["pipeline"]["slo_split"]["slo"] == rep["slo"]["target_s"]


def test_bench_pipeline_acceptance_contract():
    """The committed BENCH_pipeline.json claim, re-derived small: cascade
    beats the monolithic accurate baseline on p99 *or* replica-seconds at
    equal-or-better attainment."""
    import sys, pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))
    from benchmarks.bench_pipeline import run_cascade_vs_monolithic
    sc = _sc(duration=0.5)
    out = run_cascade_vs_monolithic(sc)
    assert out["wins"]["attainment_no_worse"]
    assert out["wins"]["p99_latency"] or out["wins"]["replica_seconds"]
