"""Workload scenarios: trace-generator determinism and statistics, and
end-to-end scenario replay through BOTH serving stacks with the shared
telemetry schema as an exact oracle (paper Figs 4/6/9 methodology)."""

import json

import numpy as np
import pytest

from repro.core.metrics import StreamingHistogram
from repro.workloads import (Scenario, ScenarioRunner, bursty_trace,
                             diurnal_trace, flash_crowd_trace, poisson_trace,
                             query_trace, run_scenario)


# ---------------------------------------------------------------------------
# trace generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda s: poisson_trace(500.0, 1.0, seed=s),
    lambda s: bursty_trace(100.0, 2000.0, 1.0, seed=s),
    lambda s: diurnal_trace(100.0, 1000.0, 1.0, seed=s),
    lambda s: flash_crowd_trace(200.0, 2000.0, 1.0, seed=s),
])
def test_traces_deterministic_sorted_in_range(make):
    a, b = make(7), make(7)
    np.testing.assert_array_equal(a, b)           # same seed, same trace
    assert len(a) > 0
    assert (np.diff(a) >= 0).all()                # sorted
    assert a[0] >= 0.0 and a[-1] < 1.0            # within [0, duration)
    c = make(8)
    assert len(c) != len(a) or not np.array_equal(a, c)


def test_poisson_rate_statistics():
    times = poisson_trace(1000.0, 4.0, seed=0)
    # E[n] = 4000, sd ~ 63: a 6-sigma band is a deterministic-safe assert
    assert 3600 < len(times) < 4400
    gaps = np.diff(times)
    assert np.mean(gaps) == pytest.approx(1e-3, rel=0.1)


def test_bursty_trace_is_actually_bursty():
    """MMPP coefficient of variation of inter-arrivals exceeds Poisson's 1."""
    t_mmpp = bursty_trace(50.0, 3000.0, 4.0, seed=3)
    gaps = np.diff(t_mmpp)
    cv = np.std(gaps) / np.mean(gaps)
    assert cv > 1.3


def test_flash_crowd_spike_window():
    times = flash_crowd_trace(100.0, 4000.0, 1.0, seed=1,
                              spike_start=0.4, spike_duration=0.2)
    in_spike = ((times >= 0.4) & (times < 0.6)).sum()
    outside = len(times) - in_spike
    # spike window is 1/4 the non-spike span but at 40x the rate
    assert in_spike > 3 * outside


def test_query_trace_pool_and_unique():
    times = poisson_trace(500.0, 0.5, seed=0)
    pooled = query_trace(times, seed=0, pool=16)
    uniq = {x.tobytes() for _, x, _ in pooled}
    assert len(uniq) <= 16
    fresh = query_trace(times, seed=0, pool=0)
    assert len({x.tobytes() for _, x, _ in fresh}) == len(times)


# ---------------------------------------------------------------------------
# scenarios through the Clipper frontend (discrete-event, virtual clock)
# ---------------------------------------------------------------------------

def test_frontend_poisson_report_byte_identical():
    a = ScenarioRunner(Scenario("t", rate=300.0, duration=1.0)).run("frontend")
    b = ScenarioRunner(Scenario("t", rate=300.0, duration=1.0)).run("frontend")
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_frontend_poisson_exact_oracles():
    rep = ScenarioRunner(Scenario("t", rate=300.0, duration=1.0,
                                  seed=5)).run("frontend")
    assert rep["schema"] == "repro.metrics/v1"
    assert rep["queries"]["completed"] == rep["queries"]["submitted"] > 0
    # light load with deadline rendering: the tail stays under the SLO
    assert rep["latency_s"]["p99"] <= rep["slo"]["target_s"]
    assert rep["slo"]["violations"] == 0
    assert rep["slo"]["rate"] == 0.0
    # zipf pool of 128 uniques at ~300 queries: the cache must be hitting
    assert rep["cache"]["hit_rate"] > 0.3
    assert rep["throughput_qps"] > 0


def test_frontend_bursty_exact_oracles():
    sc = Scenario("t", kind="bursty", rate=100.0, peak_rate=2000.0,
                  duration=1.0, seed=2)
    rep1 = ScenarioRunner(sc).run("frontend")
    rep2 = ScenarioRunner(sc).run("frontend")
    assert rep1 == rep2                           # exact, not approximate
    assert rep1["queries"]["completed"] == rep1["queries"]["submitted"]
    # bursts force multi-query dispatches: adaptive batching must kick in
    assert rep1["batch_size"]["max"] > 1
    assert rep1["latency_s"]["p99"] <= sc.slo


def test_frontend_straggler_scenario_accounting():
    rep = run_scenario("stragglers", duration=1.0)
    assert rep["stragglers"]["partial_queries"] > 0
    assert (rep["stragglers"]["dropped_models"]
            >= rep["stragglers"]["partial_queries"])
    # straggler mitigation pins P99 at the deadline (within half-bucket
    # histogram resolution), not at the straggler's 15x service time,
    # and deadline-rendered queries are not SLO violations
    assert rep["latency_s"]["p99"] <= rep["slo"]["target_s"] * 10 ** (0.5 / 24)
    assert rep["latency_s"]["max"] <= rep["slo"]["target_s"] + 1e-9
    assert rep["slo"]["violations"] == 0


def test_frontend_scaling_scenario_replicas():
    rep = run_scenario("scaling", duration=0.5)
    assert rep["scenario"]["replicas"] == 4
    assert rep["queries"]["completed"] == rep["queries"]["submitted"]


def test_report_p99_matches_reference_histogram():
    """The report's P99 equals feeding the same latencies through a fresh
    StreamingHistogram — the metric is a pure function of the observations."""
    from repro.core.frontend import make_clipper
    from repro.core.containers import linear_latency

    def fn(x):
        return np.zeros((len(x), 10), np.float32)

    clip = make_clipper({"m": fn}, "exp4", slo=0.02,
                        latency_models={"m": linear_latency(0.001, 1e-5)})
    trace = query_trace(poisson_trace(400.0, 0.5, seed=9), seed=9, pool=0)
    qids = clip.replay(trace)
    ref = StreamingHistogram(1e-6, 1e4, 24)
    for q in qids:
        ref.observe(clip.results[q].latency)
    rep = clip.report()
    assert rep["latency_s"]["p99"] == ref.percentile(99)
    assert rep["latency_s"]["p50"] == ref.percentile(50)


# ---------------------------------------------------------------------------
# the same scenarios through the LMServer (continuous batching, virtual clock)
# ---------------------------------------------------------------------------

_LM = dict(duration=0.05, rate=200.0, lm_requests=5, slots=2,
           prompt_len=4, max_new_tokens=2)


def test_lmserver_scenario_schema_matches_frontend():
    fe = ScenarioRunner(Scenario("t", rate=200.0, duration=0.2)).run("frontend")
    lm = ScenarioRunner(Scenario("t", **_LM)).run("lmserver")
    assert lm["schema"] == fe["schema"]
    # identical top-level schema except the LM-only engine section
    assert set(lm) - set(fe) == {"engine"}
    assert set(fe) - set(lm) == set()
    assert set(lm["latency_s"]) == set(fe["latency_s"])
    assert set(lm["slo"]) == set(fe["slo"])
    assert lm["stack"] == "lmserver" and fe["stack"] == "frontend"
    assert lm["queries"]["completed"] == _LM["lm_requests"]
    # virtual clock: every request has positive modeled latency
    assert lm["latency_s"]["min"] > 0


def test_lmserver_scenario_deterministic():
    a = ScenarioRunner(Scenario("t", **_LM)).run("lmserver")
    b = ScenarioRunner(Scenario("t", **_LM)).run("lmserver")
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
