"""End-to-end Clipper frontend behaviour (paper §3 + §5.2.2 + §4.2)."""

import numpy as np
import pytest

from repro.core import Feedback, linear_latency, make_clipper
from repro.core.selection import exp4_weights


def _models(rng):
    def good(x):
        return np.eye(3)[np.abs(x).sum(1).astype(int) % 3]

    def bad(x):
        return rng.normal(size=(len(x), 3))

    return {"good": good, "bad": bad}


def _trace(rng, n, gap=0.002):
    return [(i * gap, rng.normal(size=(4,)).astype(np.float32), 0)
            for i in range(n)]


def test_slo_bounded_latency_under_stragglers():
    rng = np.random.default_rng(0)
    clip = make_clipper(
        _models(rng), "exp4", slo=0.02,
        latency_models={"good": linear_latency(0.001, 1e-4),
                        "bad": linear_latency(0.002, 2e-4, p_straggle=0.05,
                                              straggle_factor=30)})
    qids = clip.replay(_trace(rng, 300))
    lat = np.array([clip.results[q].latency for q in qids])
    assert len(clip.results) == 300
    assert np.percentile(lat, 99) <= 0.02 + 1e-9
    assert any(clip.results[q].missing_models for q in qids)


def test_every_query_gets_prediction_and_confidence():
    rng = np.random.default_rng(1)
    clip = make_clipper(_models(rng), "exp4", slo=0.05,
                        latency_models={"good": linear_latency(0.001, 1e-4),
                                        "bad": linear_latency(0.001, 1e-4)})
    qids = clip.replay(_trace(rng, 50))
    for q in qids:
        p = clip.results[q]
        assert p.y is not None and 0.0 <= p.confidence <= 1.0


def test_feedback_downweights_bad_model():
    rng = np.random.default_rng(2)
    clip = make_clipper(_models(rng), "exp4", slo=0.05,
                        latency_models={"good": linear_latency(0.001, 1e-4),
                                        "bad": linear_latency(0.001, 1e-4)})
    xs = [rng.normal(size=(4,)).astype(np.float32) for _ in range(150)]
    qids = clip.replay([(i * 0.002, x, 0) for i, x in enumerate(xs)])
    for q, x in zip(qids, xs):
        clip.feedback(Feedback(q, x, int(np.abs(x).sum()) % 3))
    w = np.asarray(exp4_weights(clip.policy_state))
    ids = sorted(_models(rng))                 # ['bad', 'good']
    assert w[ids.index("good")] > 0.9


def test_feedback_join_uses_cache():
    rng = np.random.default_rng(3)
    clip = make_clipper(_models(rng), "exp4", slo=0.05,
                        latency_models={"good": linear_latency(0.001, 1e-4),
                                        "bad": linear_latency(0.001, 1e-4)})
    xs = [rng.normal(size=(4,)).astype(np.float32) for _ in range(30)]
    qids = clip.replay([(i * 0.002, x, 0) for i, x in enumerate(xs)])
    for q, x in zip(qids, xs):
        clip.feedback(Feedback(q, x, 0))
    assert clip.feedback_cache_hit_rate == 1.0   # §4.2: join hits the cache


def test_cache_serves_repeated_queries_fast():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(4,)).astype(np.float32)
    clip = make_clipper(_models(rng), "exp4", slo=0.05,
                        latency_models={"good": linear_latency(0.005, 1e-4),
                                        "bad": linear_latency(0.005, 1e-4)})
    qids = clip.replay([(i * 0.001, x, 0) for i in range(20)])
    lat = [clip.results[q].latency for q in qids]
    # after the first evaluation, identical queries resolve from cache
    assert min(lat[5:]) < 1e-6


def test_exp3_single_model_per_query():
    rng = np.random.default_rng(5)
    clip = make_clipper(_models(rng), "exp3", slo=0.05,
                        latency_models={"good": linear_latency(0.001, 1e-4),
                                        "bad": linear_latency(0.001, 1e-4)})
    qids = clip.replay(_trace(rng, 40))
    for q in qids:
        assert len(clip.results[q].model_ids) == 1
