"""Unified telemetry layer: exact histogram/percentile math, counters,
labels, SLO accounting, and the cross-stack report schema (core/metrics.py)."""

import math

import pytest

from repro.core.metrics import (BATCH_SIZE, CACHE_HITS, CACHE_MISSES,
                                LATENCY, QUERIES_COMPLETED, QUERIES_SUBMITTED,
                                SCHEMA, SERVICE, SLO_VIOLATIONS,
                                MetricsRegistry, StreamingHistogram,
                                VirtualClock)


# ---------------------------------------------------------------------------
# StreamingHistogram — exact-value percentile math
# ---------------------------------------------------------------------------

def test_histogram_exact_stats():
    h = StreamingHistogram(1e-6, 1e4, 24)
    for v in (0.001, 0.002, 0.003, 0.004):
        h.observe(v)
    assert h.count == 4
    assert h.vmin == 0.001
    assert h.vmax == 0.004
    assert h.mean == pytest.approx(0.0025, rel=1e-12)
    assert h.summary()["sum"] == pytest.approx(0.010, rel=1e-12)


def test_histogram_percentile_is_bucket_midpoint():
    """Decade buckets (bpd=1), lo=1: values 2 and 3 land in bucket [1, 10),
    whose geometric midpoint is exactly sqrt(10)."""
    h = StreamingHistogram(1.0, 1e3, 1)
    h.observe(2.0)
    h.observe(3.0)
    assert h.percentile(50) == pytest.approx(math.sqrt(10.0), rel=1e-12)
    assert h.percentile(99) == pytest.approx(math.sqrt(10.0), rel=1e-12)


def test_histogram_rank_semantics():
    """100 observations, one per decade bucket of [1, 10) and [10, 100):
    p50 must sit in the first bucket, p99 in the second."""
    h = StreamingHistogram(1.0, 1e3, 1)
    for _ in range(98):
        h.observe(5.0)            # bucket [1, 10)
    for _ in range(2):
        h.observe(50.0)           # bucket [10, 100)
    assert h.percentile(50) == pytest.approx(math.sqrt(10.0), rel=1e-12)
    assert h.percentile(98) == pytest.approx(math.sqrt(10.0), rel=1e-12)
    assert h.percentile(99) == pytest.approx(math.sqrt(1000.0), rel=1e-12)


def test_histogram_percentile_order_insensitive():
    vals = [0.5, 3.0, 700.0, 0.51, 12.0, 1.0, 80.0]
    a = StreamingHistogram(1e-2, 1e4, 8)
    b = StreamingHistogram(1e-2, 1e4, 8)
    for v in vals:
        a.observe(v)
    for v in reversed(vals):
        b.observe(v)
    for p in (1, 25, 50, 75, 95, 99, 100):
        assert a.percentile(p) == b.percentile(p)


def test_histogram_under_overflow_clamp():
    h = StreamingHistogram(1e-3, 1e3, 4)
    h.observe(1e-9)
    assert h.percentile(50) == 1e-3          # underflow reports lo
    h2 = StreamingHistogram(1e-3, 1e3, 4)
    h2.observe(1e9)
    assert h2.percentile(50) == 1e3          # overflow reports hi
    assert h2.vmax == 1e9                    # true max still tracked exactly


def test_histogram_relative_error_bound():
    """A percentile is the geometric midpoint of its bucket, so relative
    error is bounded by the half-bucket growth factor g**0.5 - 1."""
    bpd = 24
    g_half = 10.0 ** (0.5 / bpd)
    h = StreamingHistogram(1e-6, 1e4, bpd)
    v = 0.0137
    h.observe(v)
    p = h.percentile(50)
    assert v / g_half <= p <= v * g_half


def test_histogram_empty():
    h = StreamingHistogram()
    assert math.isnan(h.percentile(99))
    # schema-stable: empty summaries keep the full key set (null stats)
    s = h.summary()
    assert s["count"] == 0
    assert set(s) == {"count", "sum", "mean", "min", "max",
                      "p50", "p95", "p99"}
    assert all(s[k] is None for k in s if k != "count")


# ---------------------------------------------------------------------------
# MetricsRegistry — counters, labels, SLO, duration, schema
# ---------------------------------------------------------------------------

def test_counters_and_labels():
    m = MetricsRegistry()
    m.inc(QUERIES_SUBMITTED)
    m.inc(QUERIES_SUBMITTED, 4)
    m.inc(QUERIES_SUBMITTED, 2, model="a")
    assert m.counter(QUERIES_SUBMITTED) == 5
    assert m.counter(QUERIES_SUBMITTED, model="a") == 2
    assert m.counter("nonexistent") == 0


def test_slo_violation_accounting():
    m = MetricsRegistry(slo=0.020)
    m.observe_latency(0.001)
    m.observe_latency(0.020)                  # exactly on the deadline: OK
    m.observe_latency(0.020 + 5e-13)          # float noise: still OK
    m.observe_latency(0.021)                  # violation
    assert m.counter(SLO_VIOLATIONS) == 1
    assert m.hist(LATENCY).count == 4


def test_duration_and_throughput():
    m = MetricsRegistry(slo=1.0)
    m.mark(10.0)
    m.inc(QUERIES_COMPLETED, 50)
    m.mark(15.0)
    m.mark(12.0)                              # out-of-order marks are fine
    assert m.duration == 5.0
    assert m.report("frontend")["throughput_qps"] == pytest.approx(10.0)


def test_throughput_null_on_degenerate_mark_span():
    # no marks: duration 0 — throughput must be null, not a fabricated
    # division result
    m = MetricsRegistry(slo=1.0)
    m.inc(QUERIES_COMPLETED, 5)
    rep = m.report("frontend")
    assert rep["duration_s"] == 0.0
    assert rep["throughput_qps"] is None
    # a single mark (zero-width span) is equally degenerate
    m2 = MetricsRegistry(slo=1.0)
    m2.mark(3.0)
    m2.inc(QUERIES_COMPLETED, 5)
    assert m2.report("frontend")["throughput_qps"] is None


def test_report_schema_and_cache_rates():
    m = MetricsRegistry(slo=0.02)
    m.inc(CACHE_HITS, 3)
    m.inc(CACHE_MISSES)
    m.observe(BATCH_SIZE, 4, model="m0")
    m.observe(SERVICE, 0.002, model="m0")
    rep = m.report("frontend")
    assert rep["schema"] == SCHEMA
    assert rep["cache"]["hit_rate"] == pytest.approx(0.75)
    assert set(rep["per_model"]) == {"m0"}
    assert rep["per_model"]["m0"]["batch_size"]["count"] == 1
    # canonical top-level keys — the cross-stack contract
    assert set(rep) == {"schema", "stack", "duration_s", "queries",
                        "throughput_qps", "latency_s", "slo", "admission",
                        "cache", "batch_size", "queue_depth", "stragglers",
                        "faults", "per_model"}
    assert set(rep["slo"]) == {"target_s", "violations", "rate", "attainment"}
    assert set(rep["admission"]) == {"shed", "degraded", "shed_rate"}
    # the faults section is schema-stable: present and all-zero on a run
    # with no fault plan attached (DESIGN.md §14)
    assert set(v for v in rep["faults"].values()) == {0}


def test_report_json_stable():
    m = MetricsRegistry(slo=0.02)
    m.observe_latency(0.003)
    m.inc(QUERIES_COMPLETED)
    assert m.report_json("frontend") == m.report_json("frontend")


def test_virtual_clock():
    c = VirtualClock(5.0)
    assert c() == 5.0
    c.advance(1.5)
    assert c() == 6.5
    with pytest.raises(AssertionError):
        c.advance(-1.0)
