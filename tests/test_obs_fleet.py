"""Fleet time-series telemetry, SLO burn-rate monitor, and control-plane
decision audit log (DESIGN.md §15).

The contract mirrors the rest of the observability layer: under the
virtual clock the ``repro.timeseries/v1`` and ``repro.audit/v1``
documents are byte-identical per seed, burn-rate alerts fire and resolve
at pinned ticks on the seeded flash-crowd trace and never fire on the
healthy baseline, and every control-plane decision carries the evidence
it was made on. When the flags are off no sampler or audit object
exists, so the hot path pays a single ``is not None`` check.
"""

import json

import pytest

from repro.cluster.plan import ClusterPlan, cluster_scenario, run_plan
from repro.metrics.validate import (document_warnings, validate_audit,
                                    validate_document, validate_timeseries)
from repro.obs import (AuditLog, BurnRateMonitor, FleetSampler, MonitorConfig,
                       SeriesRing, Tracer)
from repro.obs.audit import ACTIONS
from repro.obs.export import (chrome_audit, chrome_timeseries, chrome_trace,
                              csv_audit, csv_timeseries)
from repro.workloads.scenario import Scenario, ScenarioRunner


def _fleet(interval=0.05):
    return FleetSampler(interval=interval, monitor=BurnRateMonitor())


def _run_cluster(name="flash_crowd", *, sampler=None, audit=None, **kw):
    plan = ClusterPlan(scenario=cluster_scenario(name), **kw)
    return run_plan(plan, sampler=sampler, audit=audit)


# ---------------------------------------------------------------------------
# time-series ring + sampler mechanics
# ---------------------------------------------------------------------------

def test_series_ring_bounds_memory_and_counts_dropped():
    ring = SeriesRing(capacity=8)
    for i in range(20):
        ring.append(float(i), float(i * i))
    assert len(ring) == 8
    assert ring.total == 20
    assert ring.dropped == 12
    assert ring.points()[0] == [12.0, 144.0]
    assert ring.points()[-1] == [19.0, 361.0]


def test_sample_until_stamps_exact_interval_boundaries():
    seen = []
    s = FleetSampler(interval=0.05)
    s.add_probe(lambda now, dt: seen.append((now, dt)) or {"x": now})
    s.sample_until(0.26)
    s.sample_until(0.26)            # idempotent: no duplicate stamps
    assert [t for t, _ in seen] == pytest.approx([0.05, 0.1, 0.15, 0.2, 0.25])
    assert all(dt == 0.05 for _, dt in seen)
    pts = s.to_dict()["series"]["x"]["points"]
    assert [p[0] for p in pts] == pytest.approx([0.05, 0.1, 0.15, 0.2, 0.25])
    assert s.samples == 5


def test_sampler_document_schema_and_determinism():
    def doc():
        s = _fleet()
        s.add_probe(lambda now, dt: {"q": 2.0 * now})
        s.sample_until(0.5)
        return s.to_json()
    a, b = doc(), doc()
    assert a == b
    parsed = json.loads(a)
    assert parsed["schema"] == "repro.timeseries/v1"
    assert validate_timeseries(parsed) == []


# ---------------------------------------------------------------------------
# burn-rate monitor: unit-level fire/resolve at pinned ticks
# ---------------------------------------------------------------------------

class _FakeMetrics:
    def __init__(self):
        self.done = 0
        self.viol = 0
        self.shed = 0

    def counter(self, name, *, model=None):
        from repro.core import metrics as M
        return {M.QUERIES_COMPLETED: self.done,
                M.SLO_VIOLATIONS: self.viol,
                M.QUERIES_SHED: self.shed}.get(name, 0)


def test_monitor_fires_and_resolves_at_pinned_ticks():
    cfg = MonitorConfig(objective=0.95, fast_window=0.2, slow_window=0.4,
                        burn_threshold=2.0)
    mon = BurnRateMonitor(cfg)
    m = _FakeMetrics()
    mon.bind(m)
    events = []
    for k in range(1, 21):                      # 0.05s ticks to t=1.0
        t = 0.05 * k
        m.done += 20
        if 0.3 < t <= 0.6:
            m.viol += 10                        # 50% error >> 10% budget*2
        events.extend(mon.observe(t))
    kinds = [(e["kind"], e["t"]) for e in events]
    assert kinds[0][0] == "fire"
    assert kinds[-1][0] == "resolve"
    assert len(kinds) == 2
    fire, resolve = events
    assert 0.3 < fire["t"] <= 0.6               # fires inside the bad window
    assert resolve["t"] > fire["t"]
    for key in ("burn_fast", "burn_slow", "error_fast", "error_slow",
                "threshold", "budget"):
        assert key in fire["evidence"]
    assert fire["evidence"]["burn_fast"] > cfg.burn_threshold
    assert mon.summary()["fired"] == 1 and mon.summary()["resolved"] == 1


def test_monitor_silent_when_healthy_or_unbound():
    mon = BurnRateMonitor()
    assert mon.observe(1.0) == []               # unbound: no metrics, no-op
    m = _FakeMetrics()
    mon.bind(m)
    for k in range(1, 40):
        m.done += 50                            # zero violations throughout
        assert mon.observe(0.05 * k) == []
    assert mon.summary()["fired"] == 0


def test_monitor_requires_both_windows_burning():
    # a one-tick error blip exceeds the fast window's burn but not the
    # slow window's -> multiwindow rule keeps the alert silent
    cfg = MonitorConfig(objective=0.95, fast_window=0.1, slow_window=1.0,
                        burn_threshold=2.0)
    mon = BurnRateMonitor(cfg)
    m = _FakeMetrics()
    mon.bind(m)
    fired = []
    for k in range(1, 30):
        t = 0.05 * k
        m.done += 40
        if k == 10:
            m.viol += 8                         # 20% of one tick's queries
        fired.extend(mon.observe(t))
    assert fired == []


# ---------------------------------------------------------------------------
# flash crowd end-to-end: alerts fire + resolve, byte-identical per seed
# ---------------------------------------------------------------------------

def test_flash_crowd_burn_alert_fires_and_resolves():
    sampler = _fleet()
    rep = _run_cluster("flash_crowd", sampler=sampler)
    events = sampler.to_dict()["events"]
    kinds = [e["kind"] for e in events]
    assert "fire" in kinds
    assert kinds[0] == "fire"                   # spike begins before recovery
    assert "resolve" in kinds
    fire_t = next(e["t"] for e in events if e["kind"] == "fire")
    resolve_t = next(e["t"] for e in events if e["kind"] == "resolve")
    assert fire_t < resolve_t                   # alert brackets the dip
    # the flash-crowd spike occupies the middle of the trace: the alert
    # must fire after load ramps and resolve once capacity catches up
    sc = cluster_scenario("flash_crowd")
    assert 0.0 < fire_t < sc.duration
    assert rep["queries"]["completed"] > 0


def test_flash_crowd_timeseries_and_audit_byte_identical():
    def run():
        sampler, audit = _fleet(), AuditLog()
        _run_cluster("flash_crowd", sampler=sampler, audit=audit)
        return sampler.to_json(), audit.to_json()
    (ts1, a1), (ts2, a2) = run(), run()
    assert ts1 == ts2
    assert a1 == a2
    assert validate_timeseries(json.loads(ts1)) == []
    assert validate_audit(json.loads(a1)) == []


def test_healthy_baseline_never_fires():
    sampler = _fleet()
    _run_cluster("poisson", sampler=sampler)
    assert sampler.to_dict()["events"] == []
    assert sampler.monitor.summary()["fired"] == 0


def test_alert_events_mirrored_into_span_log():
    sampler, tracer = _fleet(), Tracer(sample_rate=0.0, seed=0)
    sampler.bind(tracer=tracer)
    _run_cluster("flash_crowd", sampler=sampler)
    names = [s.name for s in tracer.spans()
             if s.trace_id == 0 and s.component == "obs.monitor"]
    assert "alert.fire" in names
    assert "alert.resolve" in names


def test_fleet_series_cover_the_vital_signs():
    sampler = _fleet()
    _run_cluster("flash_crowd", sampler=sampler)
    series = set(sampler.to_dict()["series"])
    for name in ("lambda", "throughput", "queue_depth.m0", "inflight.m0",
                 "replicas_live.m0", "est_service.m0", "aimd_budget.m0",
                 "slo.attainment_fast", "slo.burn_fast", "slo.alert_active"):
        assert name in series, name


# ---------------------------------------------------------------------------
# audit log: ring, evidence, decision counts
# ---------------------------------------------------------------------------

def test_audit_ring_bounds_but_counts_stay_exact():
    log = AuditLog(capacity=4)
    for i in range(10):
        log.record(float(i), "autoscaler", "grow", model="m0",
                   evidence={"lambda": float(i)})
    assert log.total == 10 and log.dropped == 6
    assert len(log.records()) == 4
    assert log.count("autoscaler", "grow") == 10    # exact despite drops
    assert [r["seq"] for r in log.records()] == [6, 7, 8, 9]
    assert validate_audit(log.to_dict()) == []


def test_validator_flags_unknown_actions_for_known_actors():
    log = AuditLog()
    log.record(0.0, "autoscaler", "explode")    # log accepts anything...
    errs = validate_audit(log.to_dict())
    assert any("explode" in e for e in errs)    # ...the validator objects
    assert "grow" in ACTIONS["autoscaler"]


def test_autoscaler_decisions_audited_with_evidence():
    audit = AuditLog()
    rep = _run_cluster("flash_crowd", audit=audit)
    per_model = rep["cluster"]["decisions"]["per_model"]
    grown = sum(row["grow"] for row in per_model.values())
    drained = sum(row["drain"] for row in per_model.values())
    assert grown > 0
    assert audit.count("autoscaler", "grow") == grown
    assert audit.count("autoscaler", "drain") == drained
    recs = [r for r in audit.records()
            if r["actor"] == "autoscaler" and r["action"] == "grow"]
    for r in recs:
        for key in ("lambda", "est_service_s", "backlog", "want", "live"):
            assert key in r["evidence"], key
    assert rep["cluster"]["decisions"]["audit"]["counts"] == \
        audit.summary()["counts"]


def test_admission_decisions_audited_with_expected_delay():
    audit = AuditLog()
    rep = _run_cluster("flash_crowd", audit=audit, admission="shed",
                       autoscale=False)
    shed = rep["cluster"]["decisions"]["shed"]
    assert shed > 0
    assert audit.count("admission", "shed") == shed
    rec = next(r for r in audit.records()
               if r["actor"] == "admission" and r["action"] == "shed")
    for key in ("slack_s", "expected_delay_s", "chosen"):
        assert key in rec["evidence"], key


def test_router_picks_audited_per_query():
    audit = AuditLog()
    rep = _run_cluster("poisson", audit=audit)
    routed = rep["queries"]["completed"]
    assert audit.count("router", "pick") >= routed > 0
    rec = next(r for r in audit.records() if r["actor"] == "router")
    assert "replica" in rec["evidence"]


def test_report_decisions_section_stable_without_audit():
    rep = _run_cluster("flash_crowd")
    dec = rep["cluster"]["decisions"]
    assert dec["audit"] is None                 # flag off -> no audit blob
    assert set(dec["per_model"]["m0"]) == {"grow", "drain"}
    assert dec["shed"] == 0                     # no admission policy active


# ---------------------------------------------------------------------------
# per-replica utilization in reports
# ---------------------------------------------------------------------------

def test_per_model_replica_utilization_in_report():
    rep = _run_cluster("flash_crowd")
    rows = rep["per_model"]["m0"]["replicas"]
    assert len(rows) >= 1
    for row in rows:
        assert set(row) >= {"replica", "busy_time", "utilization", "queries"}
        assert 0.0 <= row["utilization"] <= 1.0
    assert any(row["queries"] > 0 for row in rows)


# ---------------------------------------------------------------------------
# non-cluster stacks: sampled replay + LM engine probes
# ---------------------------------------------------------------------------

_LM = dict(duration=0.05, rate=200.0, lm_requests=6, slots=2,
           prompt_len=4, max_new_tokens=2, seed=11)


def test_frontend_sampled_replay_deterministic():
    def run():
        sc = Scenario("t", rate=200.0, duration=0.3, seed=11)
        sampler, audit = _fleet(0.05), AuditLog()
        rep = ScenarioRunner(sc, sampler=sampler, audit=audit).run("frontend")
        return rep, sampler.to_json(), audit.to_json()
    (r1, t1, a1), (r2, t2, a2) = run(), run()
    assert t1 == t2 and a1 == a2
    assert r1 == r2
    assert json.loads(t1)["samples"] > 0


def test_lmserver_probe_emits_model_scoped_series():
    sc = Scenario("t", **_LM)
    sampler = _fleet(0.01)
    rep = ScenarioRunner(sc, sampler=sampler).run("lmserver")
    series = set(sampler.to_dict()["series"])
    assert any(s.startswith("lm.slots_active.") for s in series)
    assert any(s.startswith("lm.queue_depth.") for s in series)
    assert any(s.startswith("lm.lambda.") for s in series)
    assert rep["engine"]["prefill"]["rung_dispatches"]
    total = sum(rep["engine"]["prefill"]["rung_dispatches"].values())
    assert total == rep["engine"]["prefill"]["dispatches"]


def test_lmcascade_probes_do_not_collide():
    import dataclasses

    from repro.pipeline.scenario import pipeline_scenario, run_lmcascade
    sc = dataclasses.replace(pipeline_scenario("pipeline"),
                             duration=0.05, rate=60.0, lm_requests=6,
                             slots=2, prompt_len=4, max_new_tokens=2, seed=11)
    sampler = _fleet(0.01)
    run_lmcascade(sc, sampler=sampler)
    doc = sampler.to_dict()
    assert validate_timeseries(doc) == []       # monotone t per series
    models = {s.rsplit(".", 1)[-1] for s in doc["series"]
              if s.startswith("lm.queue_depth.")}
    assert len(models) == 2                     # draft + verify, both present


# ---------------------------------------------------------------------------
# validation + truncation warnings
# ---------------------------------------------------------------------------

def test_validator_flags_broken_timeseries_and_audit():
    s = _fleet()
    s.add_probe(lambda now, dt: {"x": 1.0})
    s.sample_until(0.2)
    doc = s.to_dict()
    doc["series"]["x"]["points"][1][0] = 0.0    # break monotone t
    assert any("increasing" in e for e in validate_timeseries(doc))
    doc2 = s.to_dict()
    doc2["events"] = [{"t": 0.1, "kind": "resolve", "alert": "a",
                       "evidence": {}}]
    assert any("resolve" in e for e in validate_timeseries(doc2))
    log = AuditLog()
    log.record(0.0, "router", "pick", model="m0", evidence={})
    bad = log.to_dict()
    bad["counts"] = {"router.pick": 5}          # tally mismatch
    assert any("counts" in e for e in validate_audit(bad))
    assert validate_document({"schema": "repro.audit/v1"})


def test_truncation_surfaces_as_warnings_and_strict_exit(tmp_path):
    from repro.metrics.validate import main as vmain
    log = AuditLog(capacity=2)
    for i in range(5):
        log.record(float(i), "router", "pick", model="m0", evidence={})
    doc = log.to_dict()
    assert any("dropped" in w for w in document_warnings(doc))
    p = tmp_path / "audit.json"
    p.write_text(log.to_json() + "\n")
    assert vmain([str(p)]) == 0                 # warnings alone don't fail
    assert vmain(["--strict", str(p)]) != 0     # unless --strict

    tr = Tracer(sample_rate=1.0, seed=0, capacity=2)
    for i in range(5):
        root = tr.start_trace("query", "frontend", float(i))
        tr.end_trace(root, i + 0.5)
    tp = tmp_path / "trace.json"
    tp.write_text(tr.to_json() + "\n")
    assert vmain([str(tp)]) == 0
    assert vmain(["--strict", str(tp)]) != 0


def test_report_trace_section_carries_dropped():
    sc = Scenario("t", rate=400.0, duration=0.2, seed=11)
    tr = Tracer(sample_rate=1.0, seed=11, capacity=4)
    rep = ScenarioRunner(sc, tracer=tr).run("frontend")
    assert rep["trace"]["dropped"] > 0
    assert any("dropped" in w for w in document_warnings(rep))


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_chrome_timeseries_counters_and_alert_instants():
    sampler = _fleet()
    _run_cluster("flash_crowd", sampler=sampler)
    out = chrome_timeseries(sampler.to_dict())
    evs = out["traceEvents"]
    counters = [e for e in evs if e["ph"] == "C"]
    instants = [e for e in evs if e["ph"] == "i"]
    assert len(counters) > 0
    assert {e["name"] for e in instants} >= {"alert.fire", "alert.resolve"}
    assert all(e["s"] == "p" for e in instants)
    assert out["otherData"]["schema"] == "repro.timeseries/v1"
    assert chrome_timeseries(sampler.to_dict()) == out   # deterministic


def test_chrome_audit_groups_actors_into_threads():
    audit = AuditLog()
    _run_cluster("flash_crowd", audit=audit)
    out = chrome_audit(audit.to_dict())
    evs = out["traceEvents"]
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"autoscaler", "router"} <= names
    assert all(e["ph"] in ("M", "i") for e in evs)


def test_csv_exports_roundtrip(tmp_path):
    sampler, audit = _fleet(), AuditLog()
    _run_cluster("flash_crowd", sampler=sampler, audit=audit)
    ts_csv = csv_timeseries(sampler.to_dict())
    assert ts_csv.splitlines()[0] == "series,t,value"
    assert len(ts_csv.splitlines()) == 1 + sum(
        r["total"] - r["dropped"]
        for r in sampler.to_dict()["series"].values())
    a_csv = csv_audit(audit.to_dict())
    assert a_csv.splitlines()[0] == "seq,t,actor,action,model,evidence"
    assert len(a_csv.splitlines()) == 1 + len(audit.records())


def test_export_cli_mode_dispatch(tmp_path):
    from repro.obs.export import main as emain
    sampler, audit = _fleet(), AuditLog()
    _run_cluster("flash_crowd", sampler=sampler, audit=audit)
    ts, au = tmp_path / "ts.json", tmp_path / "audit.json"
    ts.write_text(sampler.to_json() + "\n")
    au.write_text(audit.to_json() + "\n")
    for src in (ts, au):
        out = tmp_path / (src.stem + ".chrome.json")
        assert emain([str(src), "-o", str(out)]) == 0    # --mode auto
        assert json.loads(out.read_text())["traceEvents"]
        csv_out = tmp_path / (src.stem + ".csv")
        assert emain([str(src), "--format", "csv",
                      "-o", str(csv_out)]) == 0
        assert csv_out.read_text().splitlines()
    with pytest.raises(SystemExit):                      # wrong schema
        emain(["--mode", "audit", str(ts), "-o", str(tmp_path / "x.json")])


def test_fault_events_exported_with_distinct_scope():
    from repro.cluster.plan import run_plan
    plan = ClusterPlan(scenario=cluster_scenario("poisson"),
                       faults=("crash:m0:0@0.3:0.8",))
    tracer = Tracer(sample_rate=1.0, seed=0)
    run_plan(plan, tracer=tracer)
    out = chrome_trace(tracer.to_dict())
    fault_instants = [e for e in out["traceEvents"]
                      if e["ph"] == "i" and e["name"].startswith("fault.")]
    assert fault_instants
    assert all(e["s"] in ("g", "p") for e in fault_instants)


# ---------------------------------------------------------------------------
# zero overhead when off
# ---------------------------------------------------------------------------

def test_fleet_flags_off_leave_report_unchanged():
    base = json.dumps(_run_cluster("poisson"), sort_keys=True)
    again = json.dumps(_run_cluster("poisson"), sort_keys=True)
    assert base == again
    rep = json.loads(base)
    assert rep["cluster"]["decisions"]["audit"] is None
    assert "trace" not in rep


def test_flags_off_probe_machinery_never_runs():
    import numpy as np

    from repro.core.frontend import make_clipper
    clip = make_clipper({"m0": lambda x: np.zeros((len(x), 10), np.float32)},
                        slo=0.02)
    for _ in range(20):
        clip.submit(np.zeros(4, np.float32))
    clip.run()
    assert clip.audit is None                   # no audit object exists
    assert clip._ts_prev == {}                  # probe never invoked


def test_build_fleet_returns_nothing_when_flags_off():
    import argparse

    from repro.obs.cli import add_fleet_args, build_fleet
    p = argparse.ArgumentParser()
    add_fleet_args(p)
    args = p.parse_args([])
    assert build_fleet(args, p) == (None, None)
    args = p.parse_args(["--timeseries-out", "/tmp/x", "--audit-out",
                         "/tmp/y"])
    sampler, audit = build_fleet(args, p)
    assert sampler is not None and audit is not None
