"""Model selection layer: Exp3 / Exp4 (paper §5.1-5.2)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.selection import (Exp3Policy, Exp4Policy, exp3_init,
                                  exp3_observe, exp3_probs, exp4_combine,
                                  exp4_init, exp4_observe, exp4_weights)


def test_exp3_converges_to_best_model():
    rng = np.random.default_rng(0)
    err = np.array([0.5, 0.1, 0.4])           # model 1 is best
    s = exp3_init(3)
    for _ in range(2000):
        p = np.asarray(exp3_probs(s))
        i = rng.choice(3, p=p / p.sum())
        loss = float(rng.random() < err[i])
        s = exp3_observe(s, jnp.int32(i), jnp.float32(loss), eta=0.1)
    assert int(np.argmax(np.asarray(exp3_probs(s)))) == 1
    assert float(exp3_probs(s)[1]) > 0.6


def test_exp4_downweights_failing_model():
    """Paper Fig 8: a degraded model loses its ensemble weight."""
    s = exp4_init(2)
    for _ in range(300):
        s = exp4_observe(s, jnp.asarray([0.9, 0.05]), eta=0.1)
    w = np.asarray(exp4_weights(s))
    assert w[1] > 0.95


def test_exp4_recovers_after_model_heals():
    """Recovery is gradual (paper Fig 8): the weight gap accumulated during
    the failure window must be won back at the healthy loss differential."""
    s = exp4_init(2)
    for _ in range(200):                       # model 0 degraded
        s = exp4_observe(s, jnp.asarray([0.9, 0.2]), eta=0.1)
    assert np.asarray(exp4_weights(s))[0] < 0.1
    for _ in range(1500):                      # model 0 recovers, now best
        s = exp4_observe(s, jnp.asarray([0.05, 0.2]), eta=0.1)
    assert np.asarray(exp4_weights(s))[0] > 0.6


def test_exp4_combine_confidence_agreement():
    s = exp4_init(3)
    agree = jnp.asarray([[0.1, 0.9], [0.2, 0.8], [0.3, 0.7]])
    y, conf = exp4_combine(s, agree)
    assert int(jnp.argmax(y)) == 1 and conf == 1.0
    split = jnp.asarray([[0.9, 0.1], [0.2, 0.8], [0.3, 0.7]])
    y2, conf2 = exp4_combine(s, split)
    assert conf2 < 1.0


def test_exp4_combine_masked_straggler():
    """§5.2.2: missing models are excluded from weights and confidence."""
    s = exp4_init(3)
    preds = jnp.asarray([[0.9, 0.1], [0.0, 0.0], [0.8, 0.2]])
    avail = jnp.asarray([True, False, True])
    y, conf = exp4_combine(s, preds, avail)
    assert int(jnp.argmax(y)) == 0
    assert conf == 1.0                        # both available models agree


@given(st.integers(2, 8), st.lists(st.floats(0.0, 1.0), min_size=2,
                                   max_size=8))
@settings(max_examples=50, deadline=None)
def test_exp_weights_remain_simplex(k, losses):
    losses = (losses + [0.0] * k)[:k]
    s = exp4_init(k)
    for _ in range(5):
        s = exp4_observe(s, jnp.asarray(losses, jnp.float32))
    w = np.asarray(exp4_weights(s))
    assert np.all(w >= 0) and abs(w.sum() - 1.0) < 1e-5
    p = np.asarray(exp3_probs(exp3_observe(exp3_init(k), jnp.int32(0),
                                           jnp.float32(losses[0]))))
    assert np.all(p >= 0) and abs(p.sum() - 1.0) < 1e-5


def test_policy_objects_listing2_interface():
    rng = np.random.default_rng(0)
    p3 = Exp3Policy(["a", "b"])
    s = p3.init()
    chosen = p3.select(s, None, rng)
    assert len(chosen) == 1 and chosen[0] in ("a", "b")
    p4 = Exp4Policy(["a", "b"])
    s4 = p4.init()
    assert p4.select(s4, None, rng) == ["a", "b"]
    y, conf = p4.combine(s4, None, {"a": np.array([1.0, 0.0]),
                                    "b": np.array([0.8, 0.2])})
    assert int(np.argmax(y)) == 0 and 0 < conf <= 1.0
