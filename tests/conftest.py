"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the single real CPU
device; only launch/dryrun.py forces 512 placeholder devices."""

import jax
import pytest
from repro.launch.mesh import compat_make_mesh


@pytest.fixture(scope="session")
def local_mesh():
    return compat_make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def train_rules_1d():
    from repro.distributed.sharding import train_rules
    return train_rules(multi_pod=False)


@pytest.fixture(scope="session")
def serve_rules_1d():
    from repro.distributed.sharding import serve_rules
    return serve_rules(multi_pod=False)
