"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
executed in interpret mode (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention_op
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm_op
from repro.kernels.rmsnorm.ref import rmsnorm_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 2, 2, 128, 64),      # MHA
    (2, 4, 2, 256, 64),      # GQA
    (1, 8, 1, 128, 128),     # MQA, MXU-aligned head dim
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_sweep(B, Hq, Hkv, S, D, dtype, causal, window):
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             q_blk=64, k_blk=64, interpret=True)
    ref = flash_attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                              jnp.swapaxes(v, 1, 2), causal=causal,
                              window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(jnp.swapaxes(ref, 1, 2), np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,Smax,D", [
    (2, 4, 4, 256, 64),
    (3, 8, 2, 512, 64),
    (1, 16, 1, 256, 128),
])
@pytest.mark.parametrize("window", [0, 128])
def test_decode_attention_sweep(B, Hq, Hkv, Smax, D, dtype, window):
    q = jnp.asarray(RNG.normal(size=(B, 1, Hq, D)), dtype)
    kc = jnp.asarray(RNG.normal(size=(B, Smax, Hkv, D)), dtype)
    vc = jnp.asarray(RNG.normal(size=(B, Smax, Hkv, D)), dtype)
    lengths = jnp.asarray(RNG.integers(1, Smax + 1, size=(B,)), jnp.int32)
    out = decode_attention_op(q, kc, vc, lengths, window=window,
                              k_blk=128, interpret=True)
    ref = decode_attention_ref(q[:, 0], jnp.swapaxes(kc, 1, 2),
                               jnp.swapaxes(vc, 1, 2), lengths,
                               window=window)[:, None]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 64), (4, 7, 96), (2, 3, 5, 128)])
def test_rmsnorm_sweep(shape, dtype):
    x = jnp.asarray(RNG.normal(size=shape), dtype)
    w = jnp.asarray(RNG.normal(size=shape[-1:]), dtype)
    out = rmsnorm_op(x, w, interpret=True)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,dk,dv,chunk", [
    (2, 3, 64, 16, 8, 16),      # mLSTM-like (dk == dv after aug)
    (1, 4, 128, 16, 64, 32),    # SSD-like (small state dim, big head dim)
    (2, 2, 32, 8, 8, 32),       # single chunk
])
def test_ssd_scan_sweep(B, H, S, dk, dv, chunk, dtype):
    from repro.kernels.ssd_scan.ops import ssd_scan_op
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    q = jnp.asarray(RNG.normal(size=(B, S, H, dk)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, H, dk)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, H, dv)), dtype)
    lf = jnp.asarray(-np.abs(RNG.normal(size=(B, S, H))), jnp.float32)
    li = jnp.asarray(-np.abs(RNG.normal(size=(B, S, H))), jnp.float32)
    y, st = ssd_scan_op(q, k, v, lf, li, chunk=chunk, interpret=True)
    yr, sr = ssd_scan_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), jnp.swapaxes(lf, 1, 2),
                          jnp.swapaxes(li, 1, 2), chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(jnp.swapaxes(yr, 1, 2), np.float32),
                               **_tol(dtype))
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_kernel_matches_model_attention_paths():
    """The kernels agree with the model-internal jnp attention (the exact
    functions the compiled steps use)."""
    from repro.models.common import attention_decode, attention_prefill
    q = jnp.asarray(RNG.normal(size=(2, 128, 4, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 128, 2, 64)), jnp.float32)
    a = attention_prefill(q, k, v, causal=True, q_block=64, k_block=64)
    b = flash_attention_op(q, k, v, causal=True, q_blk=64, k_blk=64,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)

    lengths = jnp.asarray([50, 128], jnp.int32)
    qd = q[:, :1]
    c = attention_decode(qd, k, v, lengths)
    d = decode_attention_op(qd, k, v, lengths, k_blk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(c), np.asarray(d), atol=2e-5,
                               rtol=2e-5)
