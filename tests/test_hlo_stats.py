"""HLO parser: trip-count multiplication and collective byte accounting
(the roofline methodology's foundation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_stats import analyze_hlo


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_trip_count_multiplies_dot_flops():
    D, L, B = 32, 7, 8

    def f(w, x):
        def body(h, wi):
            return h @ wi, None
        h, _ = jax.lax.scan(body, x, w)
        return h

    c = _compile(f, jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                 jax.ShapeDtypeStruct((B, D), jnp.float32))
    stats = analyze_hlo(c.as_text(), total_devices=1)
    analytic = 2.0 * L * B * D * D
    assert stats.dot_flops == pytest.approx(analytic, rel=0.05)


def test_nested_scan_trips_multiply():
    D, L1, L2 = 16, 3, 5

    def f(w, x):
        def outer(h, _):
            def inner(h2, wi):
                return h2 @ wi, None
            h, _ = jax.lax.scan(inner, h, w)
            return h, None
        h, _ = jax.lax.scan(outer, x, None, length=L1)
        return h

    c = _compile(f, jax.ShapeDtypeStruct((L2, D, D), jnp.float32),
                 jax.ShapeDtypeStruct((4, D), jnp.float32))
    stats = analyze_hlo(c.as_text(), total_devices=1)
    analytic = 2.0 * L1 * L2 * 4 * D * D
    assert stats.dot_flops == pytest.approx(analytic, rel=0.05)


def test_unknown_trip_uses_default():
    def f(x, n):
        def body(i, h):
            return h * 1.5
        return jax.lax.fori_loop(0, n, body, x)

    c = _compile(f, jax.ShapeDtypeStruct((8,), jnp.float32),
                 jax.ShapeDtypeStruct((), jnp.int32))
    stats = analyze_hlo(c.as_text(), total_devices=1, default_trip=11)
    assert stats.unknown_trip_whiles >= 1


def test_collective_bytes_counted(tmp_path):
    import subprocess, sys, textwrap
    # collectives need >1 device: run in a subprocess with forced host devices
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_stats import analyze_hlo
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((4,), ("model",))
        def f(x):
            y = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P("model")))
            return jnp.sum(y)
        with mesh:
            c = jax.jit(f, in_shardings=NamedSharding(mesh, P("model"))) \\
                .lower(jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
        s = analyze_hlo(c.as_text(), total_devices=4)
        assert s.total_collective_bytes > 0, s.to_dict()
        print("COLLECTIVE_BYTES_OK", s.total_collective_bytes)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                       "HOME": "/root"}, cwd="/root/repo")
    assert "COLLECTIVE_BYTES_OK" in r.stdout, r.stderr[-2000:]
