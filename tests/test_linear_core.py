"""Chunked linear-attention core: chunkwise-parallel form must equal the
step-by-step recurrence exactly (the invariant xlstm + hymba depend on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.linear_core import (chunked_linear_attention,
                                      linear_attention_step)


def _naive(q, k, v, log_f, log_i, S0):
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    state = S0.astype(jnp.float32)
    ys = []
    for t in range(S):
        y, state = linear_attention_step(
            state, q[:, t], k[:, t], v[:, t], log_f[:, t], log_i[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("chunk", [2, 4, 8])
@pytest.mark.parametrize("S", [8, 16])
def test_chunked_equals_stepwise(S, chunk):
    rng = np.random.default_rng(0)
    B, H, dk, dv = 2, 3, 4, 5
    q = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32)
    log_f = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))), jnp.float32)
    log_i = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))), jnp.float32)
    S0 = jnp.asarray(rng.normal(size=(B, H, dk, dv)), jnp.float32)

    y_chunk, st_chunk = chunked_linear_attention(q, k, v, log_f, log_i,
                                                 chunk=chunk, initial_state=S0)
    y_naive, st_naive = _naive(q, k, v, log_f, log_i, S0)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st_naive),
                               atol=1e-4, rtol=1e-4)


def test_differentiable():
    rng = np.random.default_rng(1)
    B, S, H, d = 1, 8, 2, 3

    def f(q):
        y, _ = chunked_linear_attention(
            q, q, q, jnp.full((B, S, H), -0.1), jnp.full((B, S, H), -0.1),
            chunk=4)
        return jnp.sum(y ** 2)

    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    g = jax.grad(f)(q)
    assert jnp.isfinite(g).all()


@given(st.integers(1, 4), st.integers(0, 1))
@settings(max_examples=20, deadline=None)
def test_state_decay_bound(nc, salt):
    """Property: with log_f <= 0 and log_i <= 0 and bounded inputs, the state
    norm never explodes (all decay ratios <= 1)."""
    rng = np.random.default_rng(salt)
    B, H, dk, dv = 1, 2, 3, 3
    S = nc * 4
    bound = lambda s: jnp.asarray(-np.abs(rng.normal(size=s)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    k = jnp.clip(jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32), -1, 1)
    v = jnp.clip(jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32), -1, 1)
    _, state = chunked_linear_attention(q, k, v, bound((B, S, H)),
                                        bound((B, S, H)), chunk=4)
    # worst case: sum of S rank-1 updates with |k||v| <= dk
    assert float(jnp.max(jnp.abs(state))) <= S * 1.0 + 1.0
