"""``hypothesis`` when installed; a tiny deterministic fallback otherwise.

The real library is strictly better (shrinking, edge-case search, a database
of past failures) — ``requirements-dev.txt`` pins it for full runs. But it is
an *optional* dependency: test collection must not die on a bare container.
The fallback implements exactly the subset this suite uses — ``@given`` over
``st.integers`` / ``st.floats`` / ``st.lists`` / ``st.tuples`` plus a no-op
``@settings`` — by running each property on the strategy boundary values
first (where defined) and then on a fixed-seed random sample, so a run is
reproducible and still exercises the corners hypothesis would try first.
"""

from __future__ import annotations

try:                                        # pragma: no cover - env dependent
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import itertools

    import numpy as np

    _DEFAULT_EXAMPLES = 25
    _SEED = 0xC11BBE2

    class _Strategy:
        """A sampler plus optional boundary examples (tried first)."""

        def __init__(self, sample, boundary=()):
            self.sample = sample
            self.boundary = tuple(boundary)

    class st:  # noqa: N801 - mirrors `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                boundary=(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                boundary=(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(n)]
            return _Strategy(sample)

        @staticmethod
        def tuples(*elems):
            return _Strategy(
                lambda rng: tuple(e.sample(rng) for e in elems))

    def settings(*, max_examples=None, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def runner():
                budget = getattr(fn, "_shim_max_examples", None) \
                    or _DEFAULT_EXAMPLES
                rng = np.random.default_rng(_SEED)
                tried = 0
                if all(s.boundary for s in strategies):
                    combos = itertools.product(*(s.boundary
                                                 for s in strategies))
                    for ex in itertools.islice(combos, min(budget, 8)):
                        fn(*ex)
                        tried += 1
                for _ in range(max(0, budget - tried)):
                    fn(*(s.sample(rng) for s in strategies))

            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
