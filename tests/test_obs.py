"""Span tracing + deadline-budget attribution (DESIGN.md §13).

The tracer's contract is the same determinism bar as the metrics layer:
under the virtual clock, two runs of the same seed produce byte-identical
``repro.trace/v1`` span logs, per-query attributions partition end-to-end
latency exactly, and child spans nest within their parents. Sampling is
head-based and a pure function of (seed, trace_id). These tests exercise
the contract on all three stacks plus the export and validation CLIs.
"""

import dataclasses
import json

import pytest

from repro.metrics.validate import (validate_document, validate_report,
                                    validate_trace)
from repro.obs import Tracer
from repro.obs.export import chrome_trace
from repro.obs.tracer import Span, SpanLog, sample_decision
from repro.workloads.scenario import Scenario, ScenarioRunner

_FE = dict(rate=200.0, duration=0.2, seed=11)
_LM = dict(duration=0.05, rate=200.0, lm_requests=5, slots=2,
           prompt_len=4, max_new_tokens=2, seed=11)


def _run_traced(stack, **kw):
    sc = Scenario("t", **kw)
    tr = Tracer(sample_rate=1.0, seed=sc.seed)
    rep = ScenarioRunner(sc, tracer=tr).run(stack)
    return rep, tr


def _run_pipeline_traced(shape="cascade"):
    from repro.pipeline.scenario import pipeline_scenario, run_pipeline
    sc = dataclasses.replace(pipeline_scenario("pipeline"),
                             duration=0.2, rate=40.0, seed=11)
    tr = Tracer(sample_rate=1.0, seed=sc.seed)
    rep = run_pipeline(sc, shape, tracer=tr)
    return rep, tr


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_sampling_deterministic_and_calibrated():
    ids = range(1, 4001)
    picks = {t for t in ids if sample_decision(7, t, 0.3)}
    assert picks == {t for t in ids if sample_decision(7, t, 0.3)}
    assert 0.2 < len(picks) / 4000 < 0.4            # calibrated to the rate
    assert picks != {t for t in ids if sample_decision(8, t, 0.3)}
    assert all(sample_decision(7, t, 1.0) for t in ids)
    assert not any(sample_decision(7, t, 0.0) for t in ids)


def test_unsampled_traces_consume_ids_and_propagate_none():
    tr = Tracer(sample_rate=0.0, seed=0)
    root = tr.start_trace("query", "frontend", 0.0)
    assert root is None
    # every downstream call tolerates the None root silently
    assert tr.start_span(root, "queue", "frontend.queue", 0.0) is None
    tr.end_span(None, 1.0)
    tr.event(root, "hit", "frontend.cache", 0.5)
    tr.end_trace(root, 1.0, attribution={"frontend.queue": 1.0})
    assert tr.traces == 1 and tr.sampled == 0
    assert len(tr.spans()) == 0
    assert tr.attribution_report()["queries"] == 0


def test_sampled_subset_identical_across_runs():
    def subset():
        tr = Tracer(sample_rate=0.5, seed=3)
        kept = []
        for i in range(200):
            root = tr.start_trace("query", "frontend", float(i))
            if root is not None:
                kept.append(root.trace_id)
                tr.end_trace(root, i + 1.0)
        return kept
    a, b = subset(), subset()
    assert a == b
    assert 0 < len(a) < 200


# ---------------------------------------------------------------------------
# ring buffer
# ---------------------------------------------------------------------------

def test_spanlog_ring_bounds_memory_and_counts_dropped():
    log = SpanLog(capacity=8)
    for i in range(20):
        log.append(Span(i, 1, None, f"s{i}", "c", float(i), end=float(i)))
    assert len(log) == 8
    assert log.total == 20
    assert log.dropped == 12
    assert [s.name for s in log.spans()] == [f"s{i}" for i in range(12, 20)]


def test_tracer_reports_drops_in_summary_and_document():
    tr = Tracer(sample_rate=1.0, seed=0, capacity=4)
    for i in range(10):
        root = tr.start_trace("query", "frontend", float(i))
        tr.end_trace(root, i + 0.5)
    doc = tr.to_dict()
    assert doc["dropped"] == 6 and len(doc["spans"]) == 4
    assert doc["spans_total"] == 10


# ---------------------------------------------------------------------------
# determinism: byte-identical span logs per seed, all three stacks
# ---------------------------------------------------------------------------

def test_frontend_trace_byte_identical_per_seed():
    _, t1 = _run_traced("frontend", **_FE)
    _, t2 = _run_traced("frontend", **_FE)
    assert t1.to_json() == t2.to_json()
    assert len(t1.spans()) > 0


def test_lmserver_trace_byte_identical_per_seed():
    _, t1 = _run_traced("lmserver", **_LM)
    _, t2 = _run_traced("lmserver", **_LM)
    assert t1.to_json() == t2.to_json()
    assert len(t1.spans()) > 0


def test_pipeline_trace_byte_identical_per_seed():
    _, t1 = _run_pipeline_traced()
    _, t2 = _run_pipeline_traced()
    assert t1.to_json() == t2.to_json()
    assert len(t1.spans()) > 0


# ---------------------------------------------------------------------------
# attribution: exact partition of end-to-end latency
# ---------------------------------------------------------------------------

def _roots(tr, name):
    return [s for s in tr.spans()
            if s.parent_id is None and s.kind == "span" and s.name == name]


@pytest.mark.parametrize("stack,root,kw", [
    ("frontend", "query", _FE),
    ("lmserver", "request", _LM),
])
def test_per_query_attribution_partitions_latency(stack, root, kw):
    rep, tr = _run_traced(stack, **kw)
    roots = _roots(tr, root)
    attributed = [r for r in roots if (r.attrs or {}).get("attribution")]
    assert attributed, "expected at least one attributed query"
    for r in attributed:
        total = sum(r.attrs["attribution"].values())
        assert total == pytest.approx(r.end - r.start, abs=1e-9)
    att = rep["latency_attribution"]
    assert att["queries"] == len(attributed)
    fracs = [c["fraction"] for c in att["components"].values()]
    assert sum(fracs) == pytest.approx(1.0, abs=1e-6)
    assert all(f >= 0 for f in fracs)


def test_pipeline_attribution_covers_stages_and_sums_to_one():
    rep, tr = _run_pipeline_traced()
    att = rep["latency_attribution"]
    assert att["queries"] > 0
    assert any(c.startswith("pipeline.stage.") for c in att["components"])
    assert sum(c["fraction"] for c in att["components"].values()) \
        == pytest.approx(1.0, abs=1e-6)
    for r in _roots(tr, "pipeline"):
        a = (r.attrs or {}).get("attribution")
        if a:
            assert sum(a.values()) == pytest.approx(r.end - r.start, abs=1e-9)


# ---------------------------------------------------------------------------
# span structure
# ---------------------------------------------------------------------------

def test_child_spans_nest_within_parent_bounds():
    for _, tr in (_run_traced("frontend", **_FE),
                  _run_traced("lmserver", **_LM)):
        doc = tr.to_dict()
        assert validate_trace(doc) == []           # includes nesting checks
        by_id = {s["span_id"]: s for s in doc["spans"]}
        checked = 0
        for s in doc["spans"]:
            p = by_id.get(s["parent_id"])
            if p is None:
                continue
            assert s["start"] >= p["start"] - 1e-9
            assert s["end"] <= p["end"] + 1e-9
            checked += 1
        assert checked > 0


def test_budget_annotations_present_on_roots_and_stages():
    _, tr = _run_traced("frontend", **_FE)
    assert all(r.budget_s is not None for r in _roots(tr, "query"))
    rep, tp = _run_pipeline_traced()
    stages = [s for s in tp.spans() if s.component == "pipeline.stage"]
    assert stages and all(s.budget_s is not None and s.budget_s > 0
                          for s in stages)
    # planner shares: each stage budget is bounded by the pipeline SLO
    slo = rep["slo"]["target_s"]
    assert all(s.budget_s <= slo + 1e-9 for s in stages)


def test_tracing_off_by_default_adds_no_report_sections():
    rep = ScenarioRunner(Scenario("t", **_FE)).run("frontend")
    assert "latency_attribution" not in rep
    assert "trace" not in rep


def test_lm_report_always_carries_engine_section():
    rep = ScenarioRunner(Scenario("t", **_LM)).run("lmserver")
    eng = rep["engine"]
    assert set(eng) == {"fused", "attention_backend", "prefill", "decode"}
    assert eng["prefill"]["dispatches"] >= 1
    assert eng["prefill"]["compiled_shapes"] == len(eng["prefill"]["shapes"])
    assert eng["decode"]["steps"] >= 1
    assert eng["decode"]["host_syncs_per_step"] is not None


# ---------------------------------------------------------------------------
# export + validation
# ---------------------------------------------------------------------------

def test_chrome_export_structure_and_determinism():
    _, tr = _run_traced("frontend", **_FE)
    doc = tr.to_dict()
    ct = chrome_trace(doc)
    evs = [e for e in ct["traceEvents"] if e["ph"] != "M"]
    assert evs
    for e in evs:
        assert e["ph"] in ("X", "i")
        assert e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 0
    spans = {s["span_id"]: s for s in doc["spans"]}
    # microsecond conversion is exact for one known span
    s = next(iter(spans.values()))
    assert any(abs(e["ts"] - s["start"] * 1e6) < 1e-6 for e in evs)
    assert json.dumps(chrome_trace(doc), sort_keys=True) \
        == json.dumps(chrome_trace(doc), sort_keys=True)


def test_chrome_export_rejects_wrong_schema():
    with pytest.raises(ValueError):
        chrome_trace({"schema": "repro.metrics/v1", "spans": []})


def test_validator_accepts_real_reports_and_traces():
    rep, tr = _run_traced("frontend", **_FE)
    assert validate_report(rep) == []
    assert validate_trace(tr.to_dict()) == []
    assert validate_document(rep) == []
    assert validate_document({"schema": "nope"}) != []


def test_validator_flags_schema_violations():
    rep, tr = _run_traced("frontend", **_FE)
    bad = dict(rep)
    bad["duration_s"] = 0
    bad["throughput_qps"] = 12.0       # must be null on a degenerate span
    assert any("throughput_qps" in e for e in validate_report(bad))
    doc = tr.to_dict()
    doc["spans"] = [dict(doc["spans"][0], start=5.0, end=1.0)]
    assert any("end" in e for e in validate_trace(doc))
    att = {"queries": 2, "total_latency_s": 1.0,
           "components": {"a": {"seconds": 0.7, "fraction": 0.7}}}
    assert any("sum" in e for e in validate_trace(
        {**tr.to_dict(), "attribution": att}))


def test_validate_cli_roundtrip(tmp_path):
    from repro.metrics.validate import main
    rep, tr = _run_traced("frontend", **_FE)
    rp = tmp_path / "report.json"
    tp = tmp_path / "trace.json"
    rp.write_text(json.dumps(rep))
    tp.write_text(tr.to_json())
    assert main([str(rp), str(tp)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": "nope"}))
    assert main([str(bad)]) == 1


def test_export_cli_roundtrip(tmp_path):
    from repro.obs.export import main
    _, tr = _run_traced("frontend", **_FE)
    src = tmp_path / "trace.json"
    out = tmp_path / "chrome.json"
    src.write_text(tr.to_json())
    assert main([str(src), "-o", str(out)]) == 0
    ct = json.loads(out.read_text())
    assert ct["traceEvents"]
    assert ct["otherData"]["schema"] == "repro.trace/v1"
