"""Fault injection, failure detection, and hedged-retry recovery
(repro.faults + Clipper RecoveryPolicy, DESIGN.md §14).

Ground truth (the plan crashing containers) is strictly separated from
observation (the frontend detecting missed completions) — these tests cover
both sides plus the recovery value claim: a crashing replica with recovery
on loses nothing, while the no-recovery baseline silently drops queries."""

import numpy as np
import pytest

from repro.cluster import ClusterPlan, SloAdmission, cluster_scenario, \
    run_plan, run_plan_json
from repro.cluster.admission import expected_delay
from repro.core import metrics as M
from repro.core.batching import AIMDController, BatchQueue
from repro.core.containers import (ContainerCrashed, JaxModelContainer,
                                   ReplicaSet, TransientError, linear_latency)
from repro.core.frontend import Clipper
from repro.core.interfaces import Query
from repro.core.selection import Exp4Policy
from repro.core.straggler import render_without
from repro.faults import (FaultPlan, FaultSpec, RecoveryPolicy,
                          RequestFaults, attach_faults, parse_fault)
from repro.metrics.validate import validate_report
from repro.obs.tracer import Tracer


def _fn(x):
    return np.zeros((len(x), 10), np.float32)


def _container(mid="m", base=0.002, per_item=1e-4, seed=0):
    return JaxModelContainer(mid, _fn, latency_model=linear_latency(
        base, per_item, rng=np.random.default_rng(seed)))


def _clip(n=2, *, recovery=None, faults=(), slo=0.05, fault_seed=0, **kw):
    rs = ReplicaSet([_container(seed=10 + i) for i in range(n)],
                    lambda: AIMDController(slo))
    clip = Clipper({"m": rs}, Exp4Policy(["m"]), slo=slo, use_cache=False,
                   recovery=recovery, **kw)
    if faults:
        attach_faults(clip.replica_sets,
                      FaultPlan.from_specs(faults, seed=fault_seed))
    return clip, rs


def _drive(clip, n=20, dt=0.004):
    qids = []
    for i in range(n):
        clip.run(until=i * dt)      # interleave events with arrivals
        qids.append(clip.submit(np.full(4, i, np.float32),
                                arrival_time=i * dt))
    clip.run()
    return qids


# ---------------------------------------------------------------------------
# plan: spec grammar, validation, seeded determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "crash:m0:0@0.25",
    "crash:m0:1@0.25:0.9",
    "flaky:m1:0:0.3",
    "slow:m0:2:4",
    "slow:m0:0:2.5@0.1:0.4",
])
def test_parse_fault_round_trips(spec):
    assert parse_fault(spec).describe() == spec


@pytest.mark.parametrize("spec", [
    "explode:m0:0@1",              # unknown kind
    "crash:m0:0",                  # crash needs @<at>
    "crash:m0:0@0.5:0.5",          # recover_at must be > at
    "flaky:m0:0:1.5",              # p out of [0, 1]
    "slow:m0:0:0",                 # factor must be > 0
    "crash:m0:x@1",                # non-int replica
])
def test_parse_fault_rejects(spec):
    with pytest.raises(ValueError):
        parse_fault(spec)


def test_replica_faults_crash_window_and_multiplier():
    rf = FaultPlan.from_specs(
        ["crash:m:0@0.2:0.5", "slow:m:0:3@0.1:0.3"]).for_replica("m", 0)
    assert not rf.crashed(0.1)
    assert rf.crashed(0.2) and rf.crashed(0.49)
    assert not rf.crashed(0.5)                      # recovered
    assert rf.multiplier(0.05) == 1.0
    assert rf.multiplier(0.15) == 3.0
    assert rf.multiplier(0.35) == 1.0
    with pytest.raises(ContainerCrashed):
        rf.check_dispatch(0.3)
    # crash striking mid-service loses the batch even though dispatch ran
    rf2 = FaultPlan.from_specs(["crash:m:0@0.2"]).for_replica("m", 0)
    rf2.check_service(0.0, 0.1)                     # finishes before crash
    with pytest.raises(ContainerCrashed):
        rf2.check_service(0.15, 0.1)


def test_transient_streams_deterministic_per_seed():
    def stream(seed):
        rf = FaultPlan.from_specs(["flaky:m:0:0.5"],
                                  seed=seed).for_replica("m", 0)
        out = []
        for _ in range(64):
            try:
                rf.check_dispatch(0.0)
                out.append(0)
            except TransientError:
                out.append(1)
        return out

    assert stream(7) == stream(7)
    assert stream(7) != stream(8)
    assert 0 < sum(stream(7)) < 64


def test_attach_faults_validates_targets():
    _, rs = _clip(2)
    with pytest.raises(KeyError):
        attach_faults({"m": rs}, FaultPlan.from_specs(["crash:nope:0@0"]))
    with pytest.raises(KeyError):
        attach_faults({"m": rs}, FaultPlan.from_specs(["crash:m:5@0"]))
    assert attach_faults({"m": rs},
                         FaultPlan.from_specs(["crash:m:0@0"])) == 1
    assert rs.has_faults and rs.replicas[0].faults is not None


# ---------------------------------------------------------------------------
# containers: the injection site
# ---------------------------------------------------------------------------

def test_container_crash_counts_failure():
    c = _container()
    attach_faults({"m": ReplicaSet([c], lambda: AIMDController(0.02))},
                  FaultPlan.from_specs(["crash:m:0@0.1"]))
    outs, service = c.pred_batch_timed([np.zeros(4)], now=0.0)
    assert len(outs) == 1 and service > 0
    with pytest.raises(ContainerCrashed):
        c.pred_batch_timed([np.zeros(4)], now=0.2)
    assert c.stats.failures == 1
    # the legacy signature stays fault-oblivious (no virtual now, no checks)
    outs, _ = c.pred_batch_timed([np.zeros(4)])
    assert len(outs) == 1


def test_container_transient_and_slow_service():
    flaky = _container()
    attach_faults({"m": ReplicaSet([flaky], lambda: AIMDController(0.02))},
                  FaultPlan.from_specs(["flaky:m:0:1"]))
    with pytest.raises(TransientError):
        flaky.pred_batch_timed([np.zeros(4)], now=0.0)
    assert flaky.stats.failures == 1
    # slow: service scales by the factor against an identically-seeded twin
    a, b = _container(seed=3), _container(seed=3)
    attach_faults({"m": ReplicaSet([b], lambda: AIMDController(0.02))},
                  FaultPlan.from_specs(["slow:m:0:4"]))
    _, sa = a.pred_batch_timed([np.zeros(4)], now=0.0)
    _, sb = b.pred_batch_timed([np.zeros(4)], now=0.0)
    assert sb == pytest.approx(4 * sa, rel=1e-9)


def test_requeue_to_keep_filter():
    make = lambda: BatchQueue(AIMDController(0.02))
    a, b = make(), make()
    for i, t in enumerate((0.3, 0.1, 0.5)):
        a.put(Query(i, 0, 0, t))
    moved = a.requeue_to(b, keep=lambda q: q.query_id != 1)
    assert moved == 2 and len(a) == 0           # dropped query not moved
    assert [q.query_id for q in b._q] == [0, 2]


def test_render_without_deterministic():
    preds = {"a": np.full(3, 1.0, np.float32),
             "b": np.full(3, 3.0, np.float32),
             "c": np.full(3, 8.0, np.float32)}
    y = render_without(["a", "b", "c"], preds, ["c"])
    assert np.allclose(y, 2.0)                  # mean of the survivors
    again = render_without(["a", "b", "c"], preds, ["c"])
    assert np.array_equal(y, again)
    # excluding every model leaves nothing to render — explicit error, not
    # a silent zero answer
    with pytest.raises(ValueError):
        render_without(["a", "b", "c"], preds, ["a", "b", "c"])


# ---------------------------------------------------------------------------
# frontend recovery: detect, requeue, retry, hedge, rejoin
# ---------------------------------------------------------------------------

def test_crash_detected_retried_and_nothing_lost():
    clip, rs = _clip(2, recovery=RecoveryPolicy(),
                     faults=("crash:m:0@0",))
    qids = _drive(clip)
    assert len(clip.results) == len(qids)       # every query answered
    assert rs.replicas[0].fail and 0 in rs.suspected
    assert clip.metrics.counter(M.FAULTS_CRASHES) >= 1
    assert clip.metrics.counter(M.FAULTS_DETECTED) == 1
    assert clip.metrics.counter(M.FAULTS_RETRIES) >= 1
    rep = clip.report()
    assert rep["faults"]["detected"] == 1
    assert rep["per_model"]["m"]["failures"] >= 1
    assert rep["per_model"]["m"]["retries"] >= 1
    assert validate_report(rep) == []


def test_no_recovery_baseline_loses_queries():
    """The value claim: with the detector off, a crashed replica is a black
    hole — batches vanish with no completion event and those queries never
    finish. Recovery on the same fault plan completes everything."""
    base, _ = _clip(2, recovery=None, faults=("crash:m:0@0",))
    _drive(base)
    rec, _ = _clip(2, recovery=RecoveryPolicy(), faults=("crash:m:0@0",))
    _drive(rec)
    lost = base.metrics.counter(M.QUERIES_SUBMITTED) \
        - base.metrics.counter(M.QUERIES_COMPLETED)
    assert lost > 0
    assert rec.metrics.counter(M.QUERIES_COMPLETED) \
        == rec.metrics.counter(M.QUERIES_SUBMITTED)


def test_crash_then_recover_rejoins_routing():
    clip, rs = _clip(2, recovery=RecoveryPolicy(),
                     faults=("crash:m:0@0:0.06",))
    qids = _drive(clip, n=40, dt=0.005)         # arrivals span the recovery
    assert len(clip.results) == len(qids)
    assert clip.metrics.counter(M.FAULTS_DETECTED) == 1
    assert clip.metrics.counter(M.FAULTS_RECOVERED) == 1
    assert not rs.replicas[0].fail and not rs.suspected
    assert 0 in rs.routable()
    # the probe reset the stale busy estimate so the replica is routable
    # immediately, not after its pre-crash free_at drains
    assert rs.free_at[0] <= clip.now


def test_transient_errors_fail_fast_and_exhaust():
    # a single always-flaky replica: every dispatch errors, every retry
    # errors again, so the per-query budget exhausts deterministically
    pol = RecoveryPolicy(max_retries=2, hedge=False)
    clip, _ = _clip(1, recovery=pol, faults=("flaky:m:0:1",))
    qids = _drive(clip, n=5)
    assert clip.metrics.counter(M.FAULTS_TRANSIENT) >= 5
    assert clip.metrics.counter(M.FAULTS_RETRIES) == 2 * len(qids)
    assert clip.metrics.counter(M.FAULTS_RETRY_EXHAUSTED) >= len(qids)
    assert len(clip.results) == 0               # no replica ever answered


def test_hedge_first_result_wins_with_exact_attribution():
    # replica 0 browns out (30x service) after a healthy warm-up, so its
    # batches suddenly outlive the history-based hedge threshold and
    # re-dispatch on replica 1, which answers first. The detector is
    # floored out of the way so hedging is isolated.
    tr = Tracer(sample_rate=1.0, seed=0)
    pol = RecoveryPolicy(min_timeout=10.0, hedge=True, hedge_min=0.01)
    clip, rs = _clip(2, recovery=pol, faults=("slow:m:0:30@0.02:10",),
                     tracer=tr)
    qids = _drive(clip)
    assert len(clip.results) == len(qids)
    assert clip.metrics.counter(M.FAULTS_HEDGES) >= 1
    assert clip.metrics.counter(M.FAULTS_HEDGE_WINS) >= 1
    assert clip.metrics.counter(M.FAULTS_SLOW) >= 1
    assert clip.report()["per_model"]["m"]["hedges"] >= 1
    # satellite: attribution stays an exact partition when a hedge wins —
    # every attributed root sums to its own end-to-end latency, and the
    # run-level fractions sum to 1
    roots = [s for s in tr.spans()
             if s.parent_id is None and s.kind == "span"
             and (s.attrs or {}).get("attribution")]
    assert roots
    for r in roots:
        assert sum(r.attrs["attribution"].values()) \
            == pytest.approx(r.end - r.start, abs=1e-9)
    att = tr.attribution_report()
    assert sum(c["fraction"] for c in att["components"].values()) \
        == pytest.approx(1.0, abs=1e-6)


def test_recovery_runs_deterministic():
    def run():
        clip, _ = _clip(2, recovery=RecoveryPolicy(),
                        faults=("crash:m:0@0:0.04", "flaky:m:1:0.2"))
        _drive(clip)
        return clip.report_json()
    assert run() == run()


def test_zero_overhead_without_plan():
    clip, rs = _clip(2)
    qids = _drive(clip)
    assert len(clip.results) == len(qids)
    assert clip._batches == {}                  # detector never armed
    assert not rs.has_faults and not rs.suspected
    rep = clip.report()
    assert set(rep["faults"].values()) == {0}
    assert validate_report(rep) == []


def test_stage_job_on_dead_model_finalizes_failed():
    # every replica of the stage's model is a permanent black hole with no
    # recovery: the stage must still finalize (empty, at the deadline) so a
    # pipeline never wedges on it — the executor counts stages_failed
    clip, _ = _clip(1, faults=("crash:m:0@0",))
    calls = []
    clip.submit_stage(["m"], np.zeros(4, np.float32), deadline=0.03,
                      finalize=lambda p, miss, late: calls.append(
                          (dict(p), miss, late)))
    clip.run()
    assert calls == [({}, ("m",), True)]


def test_validator_rejects_broken_faults_section():
    clip, _ = _clip(1)
    _drive(clip, n=3)
    rep = clip.report()
    assert validate_report(rep) == []
    bad = {**rep, "faults": {**rep["faults"], "detected": -1}}
    assert any("faults" in e for e in validate_report(bad))
    del bad["faults"]
    assert any("faults" in e for e in validate_report(bad))


# ---------------------------------------------------------------------------
# admission under total failure (satellite: SloAdmission + candidates())
# ---------------------------------------------------------------------------

def test_expected_delay_infinite_when_all_replicas_failed():
    _, rs = _clip(2)
    for r in rs.replicas:
        r.fail = True
    assert expected_delay(rs, 0.0) == float("inf")
    # regression: candidates() deliberately keeps a fallback slot so
    # recovery can drain enqueued work — admission must NOT use it
    assert rs.candidates() == [0, 1]
    assert rs.routable() == [] and rs.healthy() == []


def test_slo_admission_sheds_when_every_replica_is_down():
    clip, rs = _clip(2, admission=SloAdmission(policy="shed"))
    for r in rs.replicas:
        r.fail = True
    qid = clip.submit(np.zeros(4, np.float32), arrival_time=0.0)
    clip.run()
    assert qid in clip.shed_qids and qid not in clip.results
    assert clip.metrics.counter(M.QUERIES_SHED) == 1


# ---------------------------------------------------------------------------
# cluster driver integration
# ---------------------------------------------------------------------------

def _fault_plan(**kw):
    sc = cluster_scenario("flash_crowd", duration=0.4, seed=0)
    return ClusterPlan(scenario=sc, faults=("crash:m0:0@0.05:0.3",), **kw)


def test_cluster_run_with_faults_deterministic_and_valid():
    rep = run_plan(_fault_plan())
    assert rep["faults"]["crashes"] >= 1
    assert rep["faults"]["detected"] >= 1
    assert rep["faults"]["recovered"] >= 1
    assert validate_report(rep) == []
    assert run_plan_json(_fault_plan()) == run_plan_json(_fault_plan())


def test_cluster_recovery_beats_no_recovery():
    rec = run_plan(_fault_plan())
    base = run_plan(_fault_plan(recovery=False))
    assert rec["queries"]["completed"] > base["queries"]["completed"]
    assert rec["slo"]["attainment"] > base["slo"]["attainment"]


def test_cli_rejects_bad_specs_and_lmserver_faults():
    from repro.cluster.run import main
    with pytest.raises(SystemExit):
        main(["--scenario", "poisson", "--fault", "bogus:m0:0"])
    with pytest.raises(SystemExit):
        main(["--scenario", "poisson", "--stack", "lmserver",
              "--fault", "crash:m0:0@0.1"])


# ---------------------------------------------------------------------------
# LM stack: per-request faults + cascade degradation
# ---------------------------------------------------------------------------

def test_request_faults_pure_and_calibrated():
    rf = RequestFaults(p_error=0.3, seed=5)
    picks = [rf.failed(i) for i in range(2000)]
    assert picks == [RequestFaults(p_error=0.3, seed=5).failed(i)
                     for i in range(2000)]
    assert 0.2 < sum(picks) / 2000 < 0.4
    assert picks != [RequestFaults(p_error=0.3, seed=6).failed(i)
                     for i in range(2000)]
    assert not any(RequestFaults(p_error=0.0).failed(i) for i in range(100))


def test_lmserver_marks_failed_requests():
    import jax

    from repro.configs.registry import ARCHITECTURES, reduced_config
    from repro.distributed.sharding import serve_rules
    from repro.launch.mesh import compat_make_mesh
    from repro.models.api import build_model
    from repro.serving.engine import LMServer

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg = reduced_config(ARCHITECTURES["smollm-360m"], num_layers=2,
                         d_model=64)
    model = build_model(cfg, mesh, serve_rules(False))
    params = model.init(jax.random.PRNGKey(0))
    srv = LMServer(model, mesh, serve_rules(False), slots=2, max_len=32,
                   faults=RequestFaults(p_error=1.0, seed=0))
    rng = np.random.default_rng(0)
    rids = [srv.submit(rng.integers(0, cfg.vocab_size, size=4),
                       max_new_tokens=2) for _ in range(3)]
    srv.run(params)
    assert all(srv.completed[rid].failed for rid in rids)
    assert all(len(srv.completed[rid].tokens) == 2 for rid in rids)
    assert srv.metrics.counter(M.FAULTS_TRANSIENT) == 3


class _StubEngine:
    """Quacks like LMServer for LMCascade unit tests: shared clock, private
    registry, recorded submissions, manual on_finish firing."""

    def __init__(self, clock, model_id):
        self.clock = clock
        self.metrics = M.MetricsRegistry(0.5)
        self.model_id = model_id
        self.shed = 0
        self.on_finish = None
        self.pending = False
        self.submitted = []
        self._next = 0

    def submit(self, prompt, max_new_tokens=16, now=None):
        rid = self._next
        self._next += 1
        self.submitted.append(rid)
        return rid

    def report(self):
        return self.metrics.report("lmserver")


def _stub_cascade(**kw):
    from repro.core.metrics import VirtualClock
    from repro.pipeline.cascade import LMCascade
    from repro.serving.engine import Request

    clock = VirtualClock()
    draft = _StubEngine(clock, "draft")
    verify = _StubEngine(clock, "verify")
    casc = LMCascade(draft, verify, **kw)
    return casc, draft, verify, Request


def test_cascade_degrades_to_draft_when_verify_fails():
    casc, draft, verify, Request = _stub_cascade(
        escalate=lambda r: True)                # always verify
    cid = casc.submit(np.zeros(4, np.int32), now=0.0)
    dr = Request(0, np.zeros(4, np.int32), 4, 0.0,
                 tokens=[1, 2, 3], finish_time=0.1)
    draft.on_finish(dr)
    assert verify.submitted == [0]
    vr = Request(0, np.zeros(4, np.int32), 4, 0.1,
                 tokens=[9, 9, 9], finish_time=0.4, failed=True)
    verify.on_finish(vr)
    out = casc.results[cid]
    assert out["tier"] == "draft" and out["tokens"] == [1, 2, 3]
    assert out["latency"] == pytest.approx(0.4)  # honesty: verify-fail time
    assert casc.metrics.counter(M.QUERIES_DEGRADED) == 1


def test_cascade_escalates_failed_draft_as_retry():
    casc, draft, verify, Request = _stub_cascade(
        escalate=lambda r: False)               # would normally accept
    cid = casc.submit(np.zeros(4, np.int32), now=0.0)
    dr = Request(0, np.zeros(4, np.int32), 4, 0.0,
                 tokens=[1, 1, 1], finish_time=0.1, failed=True)
    draft.on_finish(dr)
    assert verify.submitted == [0]              # forced escalation
    assert casc.metrics.counter(M.FAULTS_RETRIES) == 1
    vr = Request(0, np.zeros(4, np.int32), 4, 0.1,
                 tokens=[5, 6, 7], finish_time=0.3)
    verify.on_finish(vr)
    assert casc.results[cid]["tier"] == "verify"
    assert casc.results[cid]["tokens"] == [5, 6, 7]
