"""Checkpointer: atomic save/restore, bf16 bit-exactness, elastic reshard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.mesh import compat_make_mesh


def _tree(rng):
    return {
        "dense": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.bfloat16),
                  "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)},
        "step": jnp.int32(7),
    }


def test_roundtrip_bitexact(tmp_path):
    rng = np.random.default_rng(0)
    t = _tree(rng)
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"params": t})
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    back = ck.restore(5, "params", shapes)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_latest_step_and_multiple(tmp_path):
    ck = Checkpointer(str(tmp_path))
    rng = np.random.default_rng(0)
    for s in (1, 3, 10):
        ck.save(s, {"params": _tree(rng)})
    assert ck.steps() == [1, 3, 10]
    assert ck.latest_step() == 10


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    rng = np.random.default_rng(0)
    ck.save(1, {"params": _tree(rng)})
    bad = jax.tree.map(lambda x: jax.ShapeDtypeStruct((9, 9), x.dtype),
                       _tree(rng))
    with pytest.raises(ValueError):
        ck.restore(1, "params", bad)


def test_elastic_restore_new_sharding(tmp_path):
    """Restore against a different sharding than the save used."""
    mesh1 = compat_make_mesh((1, 1), ("data", "model"))
    t = {"w": jax.device_put(
        jnp.arange(16, dtype=jnp.float32).reshape(4, 4),
        jax.sharding.NamedSharding(mesh1, jax.sharding.PartitionSpec()))}
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"params": t})
    sh2 = {"w": jax.sharding.NamedSharding(
        mesh1, jax.sharding.PartitionSpec("data", None))}
    shapes = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    back = ck.restore(1, "params", shapes, sh2)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(t["w"]))
    assert back["w"].sharding.spec == jax.sharding.PartitionSpec("data", None)


def test_atomic_no_partial_checkpoints(tmp_path):
    """Temp dirs never count as checkpoints."""
    ck = Checkpointer(str(tmp_path))
    (tmp_path / ".tmp_step_00000002").mkdir()
    assert ck.steps() == []
