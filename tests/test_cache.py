"""CLOCK prediction cache: unit + hypothesis property tests (paper §4.2)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cache import ClockCache, PredictionCache, digest


def test_put_fetch_roundtrip():
    c = ClockCache(4)
    c.put("a", 1)
    assert c.fetch("a") == 1
    assert c.request("a") is True
    assert c.request("zzz") is False


def test_capacity_eviction():
    c = ClockCache(3)
    for i in range(10):
        c.put(i, i * 10)
    assert len(c) == 3
    assert c.evictions == 7


def test_clock_second_chance():
    """Referenced entries survive one sweep; unreferenced are evicted first."""
    c = ClockCache(3)
    c.put("a", 1)
    c.put("b", 2)
    c.put("c", 3)
    # clear all ref bits with one full sweep
    c._ref[:] = False
    c.fetch("a")                      # re-reference only 'a'
    c.put("d", 4)                     # must evict b or c, not a
    assert "a" in c and "d" in c
    assert ("b" in c) + ("c" in c) == 1


def test_update_in_place_no_eviction():
    c = ClockCache(2)
    c.put("a", 1)
    c.put("a", 2)
    c.put("b", 3)
    assert c.fetch("a") == 2 and c.evictions == 0


def test_prediction_cache_model_scoped():
    pc = PredictionCache(8)
    x = np.arange(4, dtype=np.float32)
    pc.put("m1", x, "y1")
    assert pc.fetch("m1", x) == "y1"
    assert pc.fetch("m2", x) is None          # per-model keys (paper §4.2)


def test_digest_array_content():
    a = np.arange(4, dtype=np.float32)
    b = np.arange(4, dtype=np.float32)
    c = a.reshape(2, 2)
    assert digest(a) == digest(b)
    assert digest(a) != digest(c)


def test_digest_scalar_types_do_not_collide():
    """1, 1.0 and True hash identically in Python; the digest must keep
    their types apart or they alias as cache keys."""
    keys = {digest(1), digest(1.0), digest(True)}
    assert len(keys) == 3
    assert digest("1") not in keys
    assert digest(0) != digest(False)


def test_digest_container_types_do_not_collide():
    assert digest([1, 2]) != digest((1, 2))
    assert digest([1, 2]) == digest([1, 2])
    # nested leaves keep their types too
    assert digest((1,)) != digest((1.0,))


def test_clock_eviction_when_every_ref_bit_set():
    """Full-wrap sweep: with every resident entry referenced, the hand must
    clear all bits in one lap and evict at its original position."""
    c = ClockCache(3)
    for k in ("a", "b", "c"):
        c.put(k, k)
    assert c._hand == 0                       # wrapped during the fill
    for k in ("a", "b", "c"):
        assert c.request(k) is True           # every ref bit set
    c.put("d", 4)
    # the sweep cleared a, b, c and evicted the slot the hand started on
    assert "a" not in c and "d" in c
    assert "b" in c and "c" in c
    assert c.evictions == 1
    assert c._hand == 1                       # advanced past the victim
    assert not c._ref.any()                   # one full lap cleared all bits


def test_clock_reinsert_evicted_key_counters_and_hand():
    c = ClockCache(3)
    for k in ("a", "b", "c"):
        c.put(k, k)
    for k in ("a", "b", "c"):
        c.request(k)
    c.put("d", 4)                             # evicts 'a' (wraparound above)
    hits, misses = c.hits, c.misses
    assert c.request("a") is False            # evicted: a genuine miss
    assert c.misses == misses + 1 and c.hits == hits
    c.put("a", 10)                            # re-insert the evicted key
    # all bits were cleared by the wrap sweep, so the victim is the entry
    # under the hand ('b' in slot 1); 'a' lands there unreferenced
    assert c.fetch("a") == 10
    assert "b" not in c and "c" in c and "d" in c
    assert c.evictions == 2
    assert c._hand == 2
    assert c.request("a") is True             # present again: a hit
    assert c.hits == hits + 1


@given(st.lists(st.tuples(st.integers(0, 30), st.integers(0, 100)),
                min_size=1, max_size=200),
       st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_cache_invariants(ops, capacity):
    """Invariants: size <= capacity; a key just put is always fetchable;
    hit/miss counters consistent."""
    c = ClockCache(capacity)
    for key, val in ops:
        c.put(key, val)
        assert c.fetch(key) == val
        assert len(c) <= capacity
    assert c.hits + c.misses >= 0


@given(st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_cache_hot_key_survives(capacity):
    """A key referenced between every insertion is never evicted while the
    rest of the working set churns (CLOCK approximates LRU). Needs >= 2 cold
    slots: at total capacity 2 CLOCK correctly degrades to FIFO because every
    resident entry is referenced."""
    c = ClockCache(capacity + 1)
    c.put("hot", 0)
    for i in range(50):
        assert c.request("hot") is True
        c.put(("cold", i), i)
    assert "hot" in c
