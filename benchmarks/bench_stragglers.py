"""Paper Fig 9: stragglers vs ensemble size — (a) latency with/without
deadline rendering, (b) % queries with missing predictions, (c) accuracy.
Calibrated simulation through the real frontend event loop."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_task, train_linear_model
from repro.core import Feedback, linear_latency, make_clipper

SLO = 0.020


def _run(ensemble_size: int, rng, *, deadline: bool, n=600):
    W, label = make_task(rng)
    models = {}
    lat = {}
    for i in range(ensemble_size):
        models[f"m{i}"] = train_linear_model(
            rng, W, noise=0.2 + 0.05 * (i % 5), steps=25)
        lat[f"m{i}"] = linear_latency(0.002, 5e-5, jitter=0.1,
                                      p_straggle=0.03, straggle_factor=15,
                                      rng=rng)
    from benchmarks.common import np_call
    slo = SLO if deadline else 10.0      # no-deadline = block for everyone
    clip = make_clipper({k: np_call(v) for k, v in models.items()},
                        "exp4", slo=slo, latency_models=lat)
    xs = [rng.normal(size=(W.shape[0],)).astype(np.float32) for _ in range(n)]
    qids = clip.replay([(i * 0.004, x, 0) for i, x in enumerate(xs)])
    # tail latency + straggler accounting from the shared telemetry report
    rep = clip.report()
    miss_frac = (rep["stragglers"]["partial_queries"]
                 / max(rep["queries"]["completed"], 1))
    acc = np.mean([int(np.argmax(clip.results[q].y)) == label(x[None])[0]
                   for q, x in zip(qids, xs)])
    return (rep["latency_s"]["p99"], float(miss_frac), float(acc))


def run(rng=None) -> list:
    rng = rng or np.random.default_rng(3)
    rows = []
    for size in (2, 4, 8, 12):
        p99_block, _, acc_block = _run(size, np.random.default_rng(size),
                                       deadline=False)
        p99_dead, miss, acc_dead = _run(size, np.random.default_rng(size),
                                        deadline=True)
        rows.append({
            "name": f"fig9_stragglers/ensemble_{size}",
            "us_per_call": p99_dead * 1e6,
            "derived": (f"p99_block_ms={p99_block*1e3:.1f};"
                        f"p99_deadline_ms={p99_dead*1e3:.1f};"
                        f"pct_missing={miss*100:.0f}%;"
                        f"acc_block={acc_block:.3f};acc_dead={acc_dead:.3f}"),
        })
    return rows
