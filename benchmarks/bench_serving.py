"""Serving hot-path benchmark: fused device-resident engine vs the
reference per-slot loop (DESIGN.md §11).

Replays one seeded mixed-prompt-length trace through both engines in two
modes and emits ``BENCH_serving.json`` — the perf trajectory future PRs
compare against:

* ``sim``  — calibrated simulation (virtual clock + seeded service model):
  byte-identical numbers from a seed, the mode CI runs;
* ``wall`` — real wall-clock on this host (includes XLA compile cold
  starts, like production first-dispatch).

The headline columns are the hot-path contracts, not raw speed:
``host_syncs_per_decode_step`` (fused: 1.0, reference: 1 + active slots)
and ``prefill_compiles`` (fused: bounded by the batch×length bucket
ladders; reference: one per distinct (batch, prompt-length) pair).

    PYTHONPATH=src python benchmarks/bench_serving.py --mode both \
        --out BENCH_serving.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def _build(seed: int):
    from repro.configs.registry import ARCHITECTURES, reduced_config
    from repro.distributed.sharding import serve_rules
    from repro.launch.mesh import compat_make_mesh
    from repro.models.api import build_model

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    rules = serve_rules(False)
    cfg = reduced_config(ARCHITECTURES["smollm-360m"])
    model = build_model(cfg, mesh, rules)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, mesh, rules, model, params


def _prompts(cfg, args):
    rng = np.random.default_rng(args.seed)
    lens = rng.integers(args.min_len, args.max_len_prompt + 1,
                        size=args.requests)
    return [rng.integers(0, cfg.vocab_size, size=int(n)) for n in lens], lens


def run_engine(fused: bool, mode: str, args, built, prompts) -> dict:
    from repro.core.metrics import VirtualClock
    from repro.serving.engine import LMServer

    cfg, mesh, rules, model, params = built
    kw = dict(slots=args.slots, max_len=64, slo=0.5, temperature=0.0,
              seed=args.seed, fused=fused, model_id=cfg.name)
    if mode == "sim":
        clock = VirtualClock()

        def service_model(kind, batch, tokens):
            if kind == "prefill":
                return 0.004 + 5e-5 * batch * tokens
            return 0.001 + 5e-5 * batch

        kw.update(clock=clock, service_model=service_model)
    t0 = time.perf_counter()
    srv = LMServer(model, mesh, rules, **kw)
    for p in prompts:
        srv.submit(p, max_new_tokens=args.max_new)
    srv.run(params)
    wall = time.perf_counter() - t0
    duration = srv.metrics.duration if mode == "sim" else wall
    tokens = sum(len(r.tokens) for r in srv.completed.values())
    st = srv.stats
    return {
        "engine": "fused" if fused else "reference",
        "completed": st["completed"],
        "generated_tokens": tokens,
        "duration_s": duration,
        "tokens_per_s": tokens / duration if duration else 0.0,
        "decode_steps": st["decode_steps"],
        "steps_per_s": (st["decode_steps"] / duration) if duration else 0.0,
        "host_syncs_per_decode_step": st["host_syncs_per_decode_step"],
        "prefill_compiles": st["prefill_compiles"],
        "prefill_dispatches": st["prefill_dispatches"],
        "pad_prompts": srv.pad_prompts,
        "length_ladder": list(srv.length_ladder),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mode", choices=("sim", "wall", "both"), default="sim")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--min-len", type=int, default=4)
    ap.add_argument("--max-len-prompt", type=int, default=28)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp",
                    help="decode-attention backend (pallas runs the kernel, "
                         "in interpret mode off-TPU)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    from repro.models.common import set_attention_backend

    prev = set_attention_backend(args.backend)
    try:
        built = _build(args.seed)
        cfg = built[0]
        prompts, lens = _prompts(cfg, args)     # one trace for every run
        modes = ("sim", "wall") if args.mode == "both" else (args.mode,)
        report = {
            "schema": "repro.bench_serving/v1",
            "workload": {
                "arch": cfg.name,
                "requests": args.requests,
                "max_new_tokens": args.max_new,
                "slots": args.slots,
                "distinct_prompt_lengths": int(len(set(map(int, lens)))),
                "seed": args.seed,
                "backend": args.backend,
            },
            "modes": {m: {e["engine"]: e for e in
                          (run_engine(True, m, args, built, prompts),
                           run_engine(False, m, args, built, prompts))}
                      for m in modes},
        }
    finally:
        set_attention_backend(prev)
    with open(args.out, "w") as f:
        json.dump(report, f, sort_keys=True, indent=2)
        f.write("\n")
    for m, row in report["modes"].items():
        fu, re_ = row["fused"], row["reference"]
        print(f"[{m}] fused:     {fu['tokens_per_s']:.1f} tok/s, "
              f"{fu['host_syncs_per_decode_step']:.2f} syncs/step, "
              f"{fu['prefill_compiles']} prefill compiles")
        print(f"[{m}] reference: {re_['tokens_per_s']:.1f} tok/s, "
              f"{re_['host_syncs_per_decode_step']:.2f} syncs/step, "
              f"{re_['prefill_compiles']} prefill compiles")
    return report


if __name__ == "__main__":
    main()
