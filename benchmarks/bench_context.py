"""Paper Fig 10: contextualization — per-user ensemble selection on a
dialect-clustered task beats both the dialect-oblivious global model and the
user's designated dialect model."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_task, train_linear_model
from repro.core.context import ContextualStore
from repro.core.selection import exp4_combine

N_DIALECTS = 4
USERS_PER_DIALECT = 6


def run(rng=None) -> list:
    rng = rng or np.random.default_rng(11)
    d, k = 32, 8
    # one task variant per dialect: shared base + dialect-specific rotation
    W0 = rng.normal(size=(d, k)).astype(np.float32)
    dialect_W = []
    for _ in range(N_DIALECTS):
        R = np.eye(d, dtype=np.float32)
        idx = rng.permutation(d)[:8]
        R[idx, idx] = -1.0
        dialect_W.append((R @ W0).astype(np.float32))

    # per-dialect specialist models + one dialect-oblivious model
    specialists = [train_linear_model(rng, Wd, noise=0.15, steps=40)
                   for Wd in dialect_W]
    mixed_X = rng.normal(size=(4000, d)).astype(np.float32)
    # oblivious model: trained on a mixture (emulate by averaging weights)
    oblivious = train_linear_model(rng, np.mean(dialect_W, axis=0),
                                   noise=0.3, steps=40)

    # users have idiosyncratic accents: a 70/30 mixture of two dialects, so
    # no single specialist is ideal — the per-user ensemble can beat the
    # designated dialect model (the paper's Fig 10 finding)
    users = []
    user_W = []
    for u in range(N_DIALECTS * USERS_PER_DIALECT):
        dia = u % N_DIALECTS
        other = (dia + 1 + u % (N_DIALECTS - 1)) % N_DIALECTS
        users.append((u, dia))
        user_W.append(0.7 * dialect_W[dia] + 0.3 * dialect_W[other])
    store = ContextualStore(num_users=len(users), k=len(specialists),
                            kind="exp4", eta=0.25)

    err_oblivious = err_dialect = err_ctx = 0
    n_q = 4000
    for i in range(n_q):
        u, dia = users[i % len(users)]
        x = rng.normal(size=(1, d)).astype(np.float32)
        y = int(np.argmax(x @ user_W[u]))
        preds = np.stack([np.asarray(m(jnp.asarray(x)))[0]
                          for m in specialists])
        err_oblivious += int(int(np.argmax(np.asarray(
            oblivious(jnp.asarray(x)))[0])) != y)
        err_dialect += int(int(preds[dia].argmax()) != y)
        comb, _ = store.combine_for(u, jnp.asarray(preds))
        err_ctx += int(int(jnp.argmax(comb)) != y)
        losses = (preds.argmax(-1) != y).astype(np.float32)
        store.observe_exp4(np.asarray([u]), losses[None])

    return [
        {"name": "fig10_context/dialect_oblivious_err", "us_per_call": 0.0,
         "derived": f"{err_oblivious/n_q:.4f}"},
        {"name": "fig10_context/designated_dialect_err", "us_per_call": 0.0,
         "derived": f"{err_dialect/n_q:.4f}"},
        {"name": "fig10_context/contextual_exp4_err", "us_per_call": 0.0,
         "derived": f"{err_ctx/n_q:.4f}"},
    ]
