"""Paper §4.2: prediction caching raises feedback-processing throughput
(the paper reports 1.6x, 6K -> 11K obs/s on a 4-model ensemble) — feedback
must join with the corresponding predictions; on a cache miss every model
re-evaluates."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import D_FEAT, make_containers, np_call
from repro.core import Feedback, make_clipper
from repro.workloads import poisson_trace, query_trace


def _feedback_throughput(use_cache: bool, rng, n=300):
    fns = make_containers(rng)
    models = {k: np_call(fns[k]) for k in ("linear_svm", "mlp", "big_mlp",
                                           "kernel_svm")}
    clip = make_clipper(models, "exp4", slo=0.5, cache_size=4096,
                        use_cache=use_cache)
    times = poisson_trace(10_000.0, n / 10_000.0, seed=11)
    trace = query_trace(times, seed=12, d_feat=D_FEAT, pool=0)
    qids = clip.replay(trace)
    t0 = time.perf_counter()
    for q, (_, x, _) in zip(qids, trace):
        clip.feedback(Feedback(q, x, 0))
    dt = time.perf_counter() - t0
    return len(qids) / dt, clip.feedback_cache_hit_rate


def run(rng=None) -> list:
    rng = rng or np.random.default_rng(9)
    with_cache, hit = _feedback_throughput(True, rng)
    without, _ = _feedback_throughput(False, rng)
    return [
        {"name": "cache_feedback/with_cache", "us_per_call": 1e6 / with_cache,
         "derived": f"obs_per_s={with_cache:.0f};hit_rate={hit:.2f}"},
        {"name": "cache_feedback/without_cache", "us_per_call": 1e6 / without,
         "derived": f"obs_per_s={without:.0f}"},
        {"name": "cache_feedback/speedup", "us_per_call": 0.0,
         "derived": f"x{with_cache/without:.2f} (paper: x1.6)"},
    ]
