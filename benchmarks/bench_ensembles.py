"""Paper Figs 7 & 8: ensemble accuracy (agreement-binned) and Exp3/Exp4
under model failure. Five real JAX-trained linear models of graded quality
on a synthetic task (offline datasets are unavailable in this container —
DESIGN.md §8; the claims validated are the systems-level ones)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_task, train_linear_model
from repro.core.selection import (exp3_init, exp3_observe, exp3_probs,
                                  exp4_combine, exp4_init, exp4_observe,
                                  exp4_weights)


def _models(rng, W):
    noises = [0.55, 0.45, 0.35, 0.25, 0.12]
    return [train_linear_model(rng, W, noise=nz) for nz in noises]


def bench_ensemble_accuracy(rng) -> list:
    """Fig 7: ensemble vs best single; error binned by #models agreeing."""
    W, label = make_task(rng)
    models = _models(rng, W)
    X = rng.normal(size=(3000, W.shape[0])).astype(np.float32)
    y = label(X)
    preds = np.stack([np.asarray(m(jnp.asarray(X))) for m in models])  # [5,N,k]
    votes = preds.argmax(-1)                                           # [5,N]
    single_err = [(votes[i] != y).mean() for i in range(len(models))]
    ens = preds.mean(0).argmax(-1)
    ens_err = (ens != y).mean()
    agree = (votes == ens[None, :]).sum(0)
    rows = [{"name": "fig7_ensemble/best_single_err", "us_per_call": 0.0,
             "derived": f"{min(single_err):.4f}"},
            {"name": "fig7_ensemble/ensemble_err", "us_per_call": 0.0,
             "derived": f"{ens_err:.4f};rel_reduction="
                        f"{(min(single_err)-ens_err)/max(min(single_err),1e-9)*100:.1f}%"}]
    for k in (4, 5):
        m = agree >= k
        rows.append({"name": f"fig7_ensemble/{k}_agree", "us_per_call": 0.0,
                     "derived": f"err={(ens[m] != y[m]).mean():.4f};"
                                f"coverage={m.mean()*100:.0f}%"})
    return rows


def bench_model_failure(rng) -> list:
    """Fig 8: degrade the best model during queries 5k-10k; cumulative error
    of static models vs Exp3 vs Exp4."""
    W, label = make_task(rng)
    models = _models(rng, W)
    k = len(models)
    N = 20_000
    X = rng.normal(size=(N, W.shape[0])).astype(np.float32)
    y = label(X)
    preds = np.stack([np.asarray(m(jnp.asarray(X))) for m in models])
    # degrade model 4 (the best) during [5k, 10k): random *distributions*
    noise = rng.normal(size=preds.shape[1:]).astype(np.float32)
    noise = np.exp(noise) / np.exp(noise).sum(-1, keepdims=True)
    degraded = preds.copy()
    degraded[4, 5000:10000] = noise[5000:10000]
    votes = degraded.argmax(-1)

    s3, s4 = exp3_init(k), exp4_init(k)
    err3 = err4 = 0
    for i in range(N):
        p = np.asarray(exp3_probs(s3))
        c = int(rng.choice(k, p=p / p.sum()))
        yhat3 = votes[c, i]
        err3 += int(yhat3 != y[i])
        s3 = exp3_observe(s3, jnp.int32(c), jnp.float32(yhat3 != y[i]),
                          eta=0.15)
        comb, _ = exp4_combine(s4, jnp.asarray(degraded[:, i]))
        err4 += int(int(jnp.argmax(comb)) != y[i])
        losses = (votes[:, i] != y[i]).astype(np.float32)
        s4 = exp4_observe(s4, jnp.asarray(losses), eta=0.15)
    static_err = [(votes[j] != y).mean() for j in range(k)]
    rows = [{"name": "fig8_failure/best_static_err", "us_per_call": 0.0,
             "derived": f"{min(static_err):.4f}"},
            {"name": "fig8_failure/exp3_err", "us_per_call": 0.0,
             "derived": f"{err3/N:.4f}"},
            {"name": "fig8_failure/exp4_err", "us_per_call": 0.0,
             "derived": f"{err4/N:.4f}"},
            {"name": "fig8_failure/exp4_final_weight_on_degraded",
             "us_per_call": 0.0,
             "derived": f"{float(exp4_weights(s4)[4]):.3f}"}]
    return rows


def run(rng=None) -> list:
    rng = rng or np.random.default_rng(7)
    return bench_ensemble_accuracy(rng) + bench_model_failure(rng)
