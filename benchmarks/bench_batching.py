"""Paper Figs 3, 4, 5: latency profiles, dynamic batching strategies,
delayed batching. Real jitted models, real wall-clock measurement; the
serving loop replays open-loop arrival traces through the Clipper frontend
with latency models calibrated from the measurements."""

from __future__ import annotations

import numpy as np

from benchmarks.common import (D_FEAT, fit_linear_latency, latency_ms,
                               make_containers, model_capacity, np_call,
                               time_batch)
from repro.core import linear_latency, make_clipper
from repro.workloads import poisson_trace, query_trace

SLO = 0.020


def bench_latency_profiles(rng) -> list:
    """Fig 3: batch-size -> latency per container; max batch under the SLO."""
    rows = []
    fns = make_containers(rng)
    for name, fn in fns.items():
        lat1 = time_batch(fn, rng.normal(size=(1, D_FEAT)).astype(np.float32))
        best = 1
        for b in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024):
            lat = time_batch(fn, rng.normal(size=(b, D_FEAT)).astype(np.float32))
            if lat <= SLO:
                best = b
            else:
                break
        rows.append({"name": f"fig3_profile/{name}",
                     "us_per_call": lat1 * 1e6,
                     "derived": f"max_batch_at_20ms={best}"})
    return rows


def _throughput(kind: str, base: float, per_item: float, rng, *,
                n=3000, rate=5000.0, batch_delay=0.0,
                aimd_kwargs=None) -> "tuple[float, float]":
    """Open-loop Poisson load through the frontend; throughput and P99 come
    from the shared telemetry report instead of a private timing loop."""
    def fn(x):
        return np.zeros((len(x), 10), np.float32)

    clip = make_clipper({"m": fn}, "exp4", slo=SLO,
                        latency_models={"m": linear_latency(base, per_item)},
                        batch_delay=batch_delay,
                        aimd_kwargs=aimd_kwargs or {})
    if kind == "quantile":
        from repro.core.batching import BatchQueue, QuantileRegressionController
        rs = clip.replica_sets["m"]
        rs.queues = [BatchQueue(QuantileRegressionController(SLO), batch_delay)]
        rs.attach_metrics(clip.metrics)
    times = poisson_trace(rate, n / rate, seed=0)
    clip.replay(query_trace(times, seed=1, d_feat=D_FEAT, pool=0))
    rep = clip.report()
    return rep["throughput_qps"], rep["latency_s"]["p99"]


def bench_dynamic_batching(rng) -> list:
    """Fig 4: AIMD vs quantile regression vs no batching, 20 ms SLO.

    Latency model calibrated from the real measured linear-SVM container —
    high fixed cost, cheap per item (the paper's 26x case shape)."""
    fns = make_containers(rng)
    base, per_item = fit_linear_latency(fns["linear_svm"], rng)
    # scale to the paper's regime (fixed cost dominates single queries)
    base = max(base, 0.004)
    rows = []
    for kind, kw in (("aimd", {}), ("quantile", {}),
                     ("none", {"max_batch": 1})):
        thr, p99 = _throughput(kind if kind == "quantile" else "aimd",
                               base, per_item, rng, aimd_kwargs=kw)
        rows.append({"name": f"fig4_dynamic_batching/{kind}",
                     "us_per_call": 1e6 / thr,
                     "derived": f"qps={thr:.0f};p99_ms={p99*1e3:.2f}"})
    none_thr = 1e6 / rows[-1]["us_per_call"]
    aimd_thr = 1e6 / rows[0]["us_per_call"]
    rows.append({"name": "fig4_dynamic_batching/speedup_aimd_vs_none",
                 "us_per_call": 0.0,
                 "derived": f"x{aimd_thr / none_thr:.1f}"})
    return rows


def bench_delayed_batching(rng) -> list:
    """Fig 5: the paper frames the delayed-batching win as *efficiency* —
    "the ratio of the fixed cost for sending a batch to the variable cost of
    increasing the size of a batch" (§4.3.2). Under bursty moderate load, a
    2 ms delay stops the dispatcher from splitting bursts across batches, so
    the container capacity (queries per busy-second) rises for the
    high-fixed-cost sklearn-like container and not for the cheap-batch
    spark-like one."""
    rows = []
    # sklearn-like: fixed cost dominates (BLAS batch efficiency);
    # spark-like: per-item cost dominates (efficient at small batches)
    cases = {"sklearn_like": (0.004, 2e-6), "spark_like": (0.0001, 2e-4)}

    def fn(v):
        return np.zeros((len(v), 10), np.float32)

    for name, (base, per_item) in cases.items():
        caps = {}
        for delay in (0.0, 0.002):
            clip = make_clipper(
                {"m": fn}, "exp4", slo=SLO, batch_delay=delay,
                use_cache=False,     # unique queries; isolate batching effect
                latency_models={"m": linear_latency(base, per_item)})
            trace = []
            t = 0.0
            for _ in range(400):                     # 8-bursts @ 800 qps
                trace.extend(
                    (t + j * 1e-5,
                     rng.normal(size=(4,)).astype(np.float32), 0)
                    for j in range(8))
                t += 0.010
            clip.replay(trace)
            rep = clip.report()
            caps[delay] = model_capacity(rep, "m")
            rows.append({
                "name": f"fig5_delayed/{name}/delay_{delay*1e3:.0f}ms",
                "us_per_call": 1e6 / caps[delay] if caps[delay] else 0.0,
                "derived": (f"capacity_qps={caps[delay]:.0f};"
                            f"mean_batch={rep['batch_size']['mean']:.1f};"
                            f"p99_ms={latency_ms(rep):.1f}")})
        rows.append({"name": f"fig5_delayed/{name}/efficiency_gain",
                     "us_per_call": 0.0,
                     "derived": f"x{caps[0.002]/caps[0.0]:.2f}"})
    return rows


def run(rng=None) -> list:
    rng = rng or np.random.default_rng(0)
    return (bench_latency_profiles(rng) + bench_dynamic_batching(rng)
            + bench_delayed_batching(rng))
