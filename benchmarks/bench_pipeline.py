"""Pipeline benchmark: cascade serving vs the monolithic accurate-model
baseline, plus the intermediate-cache hit rate swept over trace skew
(DESIGN.md §12).

Everything runs as calibrated discrete-event simulation under a virtual
clock, so every number is a pure function of the seed and the emitted
``BENCH_pipeline.json`` is byte-identical across runs (CI cmp's it).

Headline contract: at equal or better SLO attainment the cascade beats the
monolithic deployment on p99 latency *and* on cost, where cost is
replica-seconds — total busy seconds across every model replica (the
quantity a cluster bill scales with). The cascade answers ~85% of queries
with the cheap draft tier and pays the accurate model only for the
low-agreement remainder, while the monolith pays it for everything.

    PYTHONPATH=src python benchmarks/bench_pipeline.py --out BENCH_pipeline.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np


def _cost_replica_seconds(rep: dict) -> float:
    """Total busy seconds across all models/replicas (service_s sums are
    exact in the shared histogram schema)."""
    return sum(pm["service_s"]["sum"] or 0.0
               for pm in rep["per_model"].values())


def _summary(rep: dict) -> dict:
    return {
        "queries": rep["queries"],
        "p50_ms": (rep["latency_s"]["p50"] or 0.0) * 1e3,
        "p99_ms": (rep["latency_s"]["p99"] or 0.0) * 1e3,
        "slo_attainment": rep["slo"]["attainment"],
        "replica_seconds": _cost_replica_seconds(rep),
        "cache_hit_rate": rep["cache"]["hit_rate"],
    }


def run_cascade_vs_monolithic(scenario) -> dict:
    """Same trace, same SLO, same accurate model: cascade pipeline vs a
    single-model deployment of the accurate model."""
    from repro.core.frontend import make_clipper
    from repro.pipeline.scenario import pipeline_models, run_pipeline
    from repro.workloads import traces as T
    from repro.workloads.scenario import D_FEAT

    casc = run_pipeline(scenario, "cascade")

    models, lat, _, _ = pipeline_models(scenario)
    mono = make_clipper({"accurate": models["accurate"]}, "exp4",
                        slo=scenario.slo, replicas=scenario.replicas,
                        latency_models={"accurate": lat["accurate"]},
                        batch_delay=scenario.batch_delay, seed=scenario.seed)
    trace = T.query_trace(scenario.arrival_times(), scenario.seed,
                          d_feat=D_FEAT, pool=scenario.pool)
    mono.replay(trace)
    mono_rep = mono.report()

    c, m = _summary(casc), _summary(mono_rep)
    return {
        "cascade": {**c,
                    "escalation_rate": casc["pipeline"]["escalation_rate"],
                    "stage_jobs": casc["pipeline"]["stage_jobs"]},
        "monolithic": m,
        "wins": {
            "p99_latency": c["p99_ms"] < m["p99_ms"],
            "replica_seconds": (c["replica_seconds"]
                                < m["replica_seconds"]),
            "attainment_no_worse": (c["slo_attainment"]
                                    >= m["slo_attainment"]),
        },
    }


def run_cache_skew_sweep(scenario, pools=(0, 64, 256, 1024)) -> list:
    """Intermediate-cache hit rate vs trace skew: ``pool=0`` is
    cache-defeating (every query unique); small Zipf pools concentrate
    mass on few queries, so whole pipeline prefixes resolve from cache."""
    from repro.pipeline.scenario import run_pipeline

    rows = []
    for pool in pools:
        sc = dataclasses.replace(scenario, pool=pool)
        rep = run_pipeline(sc, "cascade")
        rows.append({
            "pool": pool,
            "cache_hit_rate": rep["cache"]["hit_rate"],
            "per_model_hit_rate": {
                m: pm["cache"]["hit_rate"]
                for m, pm in sorted(rep["per_model"].items())},
            "p99_ms": (rep["latency_s"]["p99"] or 0.0) * 1e3,
            "replica_seconds": _cost_replica_seconds(rep),
        })
    return rows


def build_report(seed: int = 0) -> dict:
    from repro.pipeline.scenario import pipeline_scenario

    sc = pipeline_scenario(seed=seed)
    return {
        "bench": "pipeline",
        "scenario": dataclasses.asdict(sc),
        "cascade_vs_monolithic": run_cascade_vs_monolithic(sc),
        "cache_skew_sweep": run_cache_skew_sweep(sc),
    }


# -- harness contract (benchmarks/run.py) -----------------------------------

def run(rng: np.random.Generator = None) -> list:
    rep = build_report()
    cvm = rep["cascade_vs_monolithic"]
    rows = []
    for name in ("cascade", "monolithic"):
        r = cvm[name]
        rows.append({
            "name": f"pipeline/{name}",
            "us_per_call": r["p99_ms"] * 1e3,
            "derived": (f"attainment={r['slo_attainment']:.3f};"
                        f"replica_s={r['replica_seconds']:.3f}"),
        })
    for row in rep["cache_skew_sweep"]:
        rows.append({
            "name": f"pipeline_cache/pool_{row['pool']}",
            "us_per_call": row["p99_ms"] * 1e3,
            "derived": f"hit_rate={row['cache_hit_rate']:.3f}",
        })
    return rows


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args(argv)
    rep = build_report(seed=args.seed)
    text = json.dumps(rep, sort_keys=True, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    return rep


if __name__ == "__main__":
    main()
