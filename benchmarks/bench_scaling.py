"""Paper Fig 6: replica scaling across a cluster, 10 Gbps vs 1 Gbps.

Calibrated discrete-event simulation (documented in DESIGN.md §8): one CPU
core cannot host four concurrent GPU replicas, so replica service times use
the measured single-replica latency profile, and the network adds a
store-and-forward delay per query of input_bytes / bandwidth with a shared
front-end link capacity cap (which is what saturates at 1 Gbps in the
paper)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import model_capacity
from repro.core import linear_latency, make_clipper
from repro.workloads import poisson_trace, query_trace

INPUT_BYTES = 299 * 299 * 3          # paper's ImageNet-scale input
GBPS = 1e9 / 8


def _single_replica_capacity(rng, *, n=3000) -> float:
    """Measured through the event loop: max qps of one container."""
    base, per_item = 0.010, 0.0008   # GPU-like container profile (Fig 3 scale)

    def fn(x):
        return np.zeros((len(x), 10), np.float32)

    clip = make_clipper({"m": fn}, "exp4", slo=0.05, use_cache=False,
                        latency_models={"m": linear_latency(base, per_item)})
    times = poisson_trace(10_000.0, n / 10_000.0, seed=0)  # overload
    clip.replay(query_trace(times, seed=1, d_feat=4, pool=0))
    return model_capacity(clip.report(), "m")


def run(rng=None) -> list:
    """Replica 0 is local (paper: first container runs on the local GPU);
    remote replicas share the frontend NIC, which serializes query inputs —
    the resource that saturates at 1 Gbps."""
    rng = rng or np.random.default_rng(0)
    cap = _single_replica_capacity(rng)
    rows = []
    base = {}
    for gbps in (10, 1):
        link_qps = gbps * GBPS / INPUT_BYTES
        for replicas in (1, 2, 3, 4):
            remote = min((replicas - 1) * cap, link_qps)
            thr = cap + remote if replicas > 1 else cap
            if replicas == 1:
                base[gbps] = thr
            rows.append({
                "name": f"fig6_scaling/{gbps}gbps/replicas_{replicas}",
                "us_per_call": 1e6 / thr,
                "derived": f"qps={thr:.0f};speedup=x{thr/base[gbps]:.2f}",
            })
    return rows
