"""Shared benchmark substrate: real jitted model containers of graded cost
(the paper's linear-SVM .. kernel-SVM spectrum), synthetic tasks, timing."""

from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

D_FEAT = 64
N_CLASSES = 10


def make_containers(rng: np.random.Generator) -> Dict[str, Callable]:
    """Real jitted predictors spanning ~3 orders of magnitude of cost
    (paper Fig 3's model spectrum, on CPU)."""
    w_lin = jnp.asarray(rng.normal(size=(D_FEAT, N_CLASSES)) * 0.1)
    w1 = jnp.asarray(rng.normal(size=(D_FEAT, 512)) * 0.1)
    w2 = jnp.asarray(rng.normal(size=(512, N_CLASSES)) * 0.1)
    wb1 = jnp.asarray(rng.normal(size=(D_FEAT, 2048)) * 0.1)
    wb2 = jnp.asarray(rng.normal(size=(2048, 2048)) * 0.1)
    wb3 = jnp.asarray(rng.normal(size=(2048, N_CLASSES)) * 0.1)
    support = jnp.asarray(rng.normal(size=(4096, D_FEAT)))
    alpha = jnp.asarray(rng.normal(size=(4096, N_CLASSES)) * 0.01)

    @jax.jit
    def linear_svm(x):
        return x @ w_lin

    @jax.jit
    def mlp(x):
        return jax.nn.relu(x @ w1) @ w2

    @jax.jit
    def big_mlp(x):
        return jax.nn.relu(jax.nn.relu(x @ wb1) @ wb2) @ wb3

    @jax.jit
    def kernel_svm(x):
        d2 = ((x[:, None, :] - support[None, :, :]) ** 2).sum(-1)
        return jnp.exp(-0.01 * d2) @ alpha

    @jax.jit
    def noop(x):
        return x[:, :N_CLASSES]

    return {"linear_svm": linear_svm, "mlp": mlp, "big_mlp": big_mlp,
            "kernel_svm": kernel_svm, "noop": noop}


def np_call(fn: Callable) -> Callable:
    return lambda x: np.asarray(fn(jnp.asarray(x)))


def time_batch(fn: Callable, x: np.ndarray, iters: int = 5) -> float:
    """Median wall-clock seconds for one batched call (post-warmup)."""
    xj = jnp.asarray(x)
    jax.block_until_ready(fn(xj))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(xj))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def fit_linear_latency(fn: Callable, rng, sizes=(1, 4, 16, 64, 256)
                       ) -> Tuple[float, float]:
    """Measure the latency profile, return (base_s, per_item_s)."""
    xs, ys = [], []
    for b in sizes:
        x = rng.normal(size=(b, D_FEAT)).astype(np.float32)
        xs.append(b)
        ys.append(time_batch(fn, x))
    a = float(np.cov(xs, ys, bias=True)[0, 1] / np.var(xs))
    b0 = float(np.median(np.asarray(ys) - a * np.asarray(xs)))
    return max(b0, 1e-6), max(a, 1e-9)


# ---------------------------------------------------------------------------
# synthetic classification task + quickly-trained jax models (Figs 7/8/10)
# ---------------------------------------------------------------------------

def make_task(rng, d=D_FEAT, k=N_CLASSES):
    W = rng.normal(size=(d, k)).astype(np.float32)

    def label(x: np.ndarray) -> np.ndarray:
        return np.argmax(x @ W, axis=-1)

    return W, label


def train_linear_model(rng, W_true, *, noise: float, n_train: int = 2000,
                       steps: int = 60, feature_mask: np.ndarray = None):
    """Train a linear softmax model on noisy data — graded model quality."""
    d, k = W_true.shape
    X = rng.normal(size=(n_train, d)).astype(np.float32)
    y = np.argmax(X @ W_true, axis=-1)
    flip = rng.random(n_train) < noise
    y = np.where(flip, rng.integers(0, k, n_train), y)
    mask = np.ones(d, np.float32) if feature_mask is None else feature_mask
    Xj, yj = jnp.asarray(X * mask), jnp.asarray(y)

    def loss(w):
        logits = Xj @ w
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(yj)), yj])

    w = jnp.zeros((d, k))
    lr = 0.5
    g = jax.jit(jax.grad(loss))
    for _ in range(steps):
        w = w - lr * g(w)

    @jax.jit
    def predict(x):
        return jax.nn.softmax((x * mask) @ w)

    return predict


# ---------------------------------------------------------------------------
# telemetry adapters: benches consume the shared repro.metrics/v1 reports
# (core/metrics.py) instead of private timing loops
# ---------------------------------------------------------------------------

def model_busy_time(report: dict, model_id: str) -> float:
    """Total service seconds a model spent evaluating batches (the
    histogram's exactly-tracked sum)."""
    s = report["per_model"][model_id]["service_s"]
    return s["sum"] if s["count"] else 0.0

def model_capacity(report: dict, model_id: str) -> float:
    """Queries per busy-second — the container's efficiency under the
    observed batching (Fig 5's capacity metric)."""
    busy = model_busy_time(report, model_id)
    return report["per_model"][model_id]["queries"] / busy if busy else 0.0

def latency_ms(report: dict, p: str = "p99") -> float:
    return report["latency_s"][p] * 1e3
