"""Robustness benchmark: flash-crowd serving through a crash-then-recover
replica, with the recovery layer (failure detection + requeue + retries +
hedging, DESIGN.md §14) on versus off.

The fault plan crashes one replica of the hottest model a quarter of the way
into the flash crowd and brings it back near the end — with recovery off the
crashed replica is a black hole (its dispatched batches vanish, queued work
strands, LECT routing keeps feeding its stale estimate), so the run loses
queries outright. Calibrated discrete-event simulation under a virtual
clock: every number is a pure function of the seed and the report is
byte-identical across runs (CI cmp's it).

    PYTHONPATH=src python benchmarks/bench_faults.py --out BENCH_faults.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

FAULTS = ("crash:m0:0@0.25:0.9",)


def _arm(rep: dict) -> dict:
    return {
        "completed": rep["queries"]["completed"],
        "submitted": rep["queries"]["submitted"],
        "slo_attainment": rep["slo"]["attainment"],
        "p99_ms": rep["latency_s"]["p99"] * 1e3,
        "faults": rep["faults"],
    }


def run_crash_recover(sc) -> dict:
    from repro.cluster import ClusterPlan, run_plan

    arms = {}
    for recovery in (True, False):
        rep = run_plan(ClusterPlan(scenario=sc, faults=FAULTS,
                                   recovery=recovery))
        arms["recovery" if recovery else "no_recovery"] = _arm(rep)
    healthy = run_plan(ClusterPlan(scenario=sc))
    arms["healthy"] = _arm(healthy)
    rec, base = arms["recovery"], arms["no_recovery"]
    arms["wins"] = {
        "queries_saved": rec["completed"] - base["completed"],
        "attainment_gain": rec["slo_attainment"] - base["slo_attainment"],
        "attainment_vs_healthy":
            rec["slo_attainment"] - arms["healthy"]["slo_attainment"],
    }
    return arms


def build_report(seed: int = 0) -> dict:
    from repro.cluster import cluster_scenario

    sc = cluster_scenario("flash_crowd", seed=seed)
    return {
        "bench": "faults",
        "scenario": dataclasses.asdict(sc),
        "fault_plan": list(FAULTS),
        "crash_recover": run_crash_recover(sc),
    }


# -- harness contract (benchmarks/run.py) -----------------------------------

def run(rng: np.random.Generator = None) -> list:
    rep = build_report()
    rows = []
    for name in ("recovery", "no_recovery", "healthy"):
        r = rep["crash_recover"][name]
        rows.append({
            "name": f"faults/crash_recover/{name}",
            "us_per_call": r["p99_ms"] * 1e3,
            "derived": (f"attainment={r['slo_attainment']:.3f};"
                        f"completed={r['completed']}/{r['submitted']}"),
        })
    return rows


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args(argv)
    rep = build_report(seed=args.seed)
    text = json.dumps(rep, sort_keys=True, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    return rep


if __name__ == "__main__":
    main()
