"""Control-plane benchmark: flash-crowd SLO attainment with and without the
autoscaler, plus admission-controlled overload (DESIGN.md §10).

Calibrated discrete-event simulation under a virtual clock — the replica
counts and every latency are a pure function of the seed, so the rows are
reproducible. ``us_per_call`` reports the end-to-end P99; ``derived``
carries attainment and the replica excursion (steady -> peak -> final)."""

from __future__ import annotations

from repro.cluster import ClusterPlan, cluster_scenario, run_plan


def _row(name: str, rep: dict, extra: str = "") -> dict:
    att = rep["slo"]["attainment"]
    derived = f"attainment={att:.3f}"
    if extra:
        derived += ";" + extra
    return {"name": name, "us_per_call": rep["latency_s"]["p99"] * 1e6,
            "derived": derived}


def run(rng=None) -> list:
    rows = []
    sc = cluster_scenario("flash_crowd")
    for autoscale in (False, True):
        rep = run_plan(ClusterPlan(scenario=sc, autoscale=autoscale))
        if autoscale:
            a = rep["cluster"]["autoscalers"][0]
            extra = (f"replicas=1->{a['peak_live']}->{a['live']};"
                     f"added={a['added']};retired={a['retired']}")
        else:
            extra = "replicas=fixed_1"
        label = "on" if autoscale else "off"
        rows.append(_row(f"cluster_autoscale/flash_crowd/{label}", rep, extra))
    # admission control under sustained overload (no autoscaling): early
    # shedding keeps the served tail inside the SLO regime
    over = cluster_scenario("poisson", rate=1500.0, duration=1.0)
    for policy in (None, "shed"):
        rep = run_plan(ClusterPlan(scenario=over, autoscale=False,
                                   admission=policy))
        extra = (f"shed={rep['admission']['shed']};"
                 f"completed={rep['queries']['completed']}")
        label = policy or "off"
        rows.append(_row(f"cluster_admission/overload/{label}", rep, extra))
    return rows
