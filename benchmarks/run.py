"""Benchmark harness — one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract). Roofline
numbers come from the dry-run artifacts (launch/roofline.py), not from here.
"""

from __future__ import annotations

import sys
import time

import numpy as np


def main() -> None:
    from benchmarks import (bench_autoscale, bench_batching, bench_cache,
                            bench_context, bench_ensembles, bench_faults,
                            bench_overhead, bench_pipeline, bench_scaling,
                            bench_stragglers)

    suites = [
        ("fig3/4/5 batching", bench_batching),
        ("fig6 scaling", bench_scaling),
        ("fig7/8 ensembles", bench_ensembles),
        ("fig9 stragglers", bench_stragglers),
        ("fig10 context", bench_context),
        ("fig11 overhead", bench_overhead),
        ("sec4.2 cache", bench_cache),
        ("control plane", bench_autoscale),
        ("pipelines", bench_pipeline),
        ("faults", bench_faults),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for label, mod in suites:
        if only and only not in label and only not in mod.__name__:
            continue
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # pragma: no cover — keep the harness running
            print(f"{mod.__name__}/ERROR,0,{type(e).__name__}:{e}")
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
        print(f"# {label}: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
