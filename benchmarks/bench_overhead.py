"""Paper Fig 11 (TensorFlow-Serving comparison): Clipper's layered frontend
vs a tightly-integrated direct jit call on the same models. The direct path
is our stand-in for TF-Serving (single model, no cache/selection layers);
the claim reproduced is that the modular stack adds minimal overhead at
sustained throughput."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import D_FEAT, make_containers, np_call, time_batch
from repro.core import make_clipper


def _direct_throughput(fn, batch: int, rng, secs: float = 1.0):
    x = jnp.asarray(rng.normal(size=(batch, D_FEAT)).astype(np.float32))
    jax.block_until_ready(fn(x))
    n = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < secs:
        jax.block_until_ready(fn(x))
        n += batch
    return n / (time.perf_counter() - t0)


def _clipper_throughput(fn, batch: int, rng, secs: float = 1.0):
    clip = make_clipper({"m": np_call(fn)}, "exp4", slo=0.1, cache_size=16,
                        aimd_kwargs={"init": batch, "max_batch": batch})
    n = 0
    t0 = time.perf_counter()
    i = 0
    while time.perf_counter() - t0 < secs:
        # submit one full batch then drain — sustained-throughput regime
        for j in range(batch):
            clip.submit(rng.normal(size=(D_FEAT,)).astype(np.float32),
                        arrival_time=clip.now)
        clip.run()
        n += batch
        i += 1
    return n / (time.perf_counter() - t0)


def run(rng=None) -> list:
    rng = rng or np.random.default_rng(5)
    fns = make_containers(rng)
    rows = []
    cases = {"mnist_like": ("mlp", 512), "cifar_like": ("big_mlp", 128),
             "imagenet_like": ("kernel_svm", 16)}
    for label, (name, batch) in cases.items():
        direct = _direct_throughput(fns[name], batch, rng)
        clipper = _clipper_throughput(fns[name], batch, rng)
        rows.append({
            "name": f"fig11_overhead/{label}",
            "us_per_call": 1e6 / clipper,
            "derived": (f"direct_qps={direct:.0f};clipper_qps={clipper:.0f};"
                        f"ratio={clipper/direct:.2f}"),
        })
    return rows
